"""Vamana-style proximity graph: offline numpy build + jittable beam search.

Hardware adaptation (DESIGN.md §3): CPU Vamana is sequential pointer
chasing with data-dependent termination. The TPU-native form is a
**fixed-iteration, fixed-pool best-first search** — `lax.fori_loop` over
L steps, each step expanding the best unexpanded pool entry via a row
gather of its neighbor list and one fused distance block, then a
sort-merge (dedup by sort adjacency) back into the pool. All shapes are
static; convergence turns further iterations into masked no-ops.

The build replaces Vamana's greedy RobustPrune (a per-point sequential
loop) with a **one-shot vectorised occlusion prune** over candidate pools
drawn from IVF locality: candidate j (in ascending-distance order) is
dropped iff some closer candidate u occludes it (α·d(u,j) < d(q,j)).
This is the standard vectorisation of α-pruning and keeps the build
O(N·C²) fully inside BLAS.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann import ivf as ivf_mod

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass
class VamanaGraph:
    neighbors: np.ndarray     # [N, R] int32 (−1 pad)
    medoid: int
    label_entry: np.ndarray   # [U] int32 entry point per label (−1 if unused)


def occlusion_prune(cid: np.ndarray, cdist: np.ndarray, vectors: np.ndarray,
                    norms: np.ndarray, alpha: float, keep_n: int) -> np.ndarray:
    """Vectorised α-occlusion prune over candidate pools.

    `cid`/`cdist` are [B, C] pools in *ascending-distance order* (−1/inf
    pad); returns [B, keep_n] selected edge targets (−1 pad): candidate j
    is dropped iff some closer candidate u occludes it (α·d(u,j) < d(q,j)).
    Shared by the offline build and `graft_graph`.
    """
    b, c = cid.shape
    cv = vectors[np.maximum(cid, 0)]                              # [B, C, d]
    cn = norms[np.maximum(cid, 0)]
    # pairwise distances among candidates
    gram = np.einsum("bud,bjd->buj", cv, cv, optimize=True)
    d2 = cn[:, :, None] + cn[:, None, :] - 2.0 * gram             # [B, C, C]
    tri = np.tril(np.ones((c, c), dtype=bool), k=-1)[None]        # u < j
    occl = tri & (alpha * d2 < cdist[:, None, :]) \
        & (cid[:, :, None] >= 0) & (cid[:, None, :] >= 0)
    dominated = occl.any(axis=1)                                  # [B, C]
    keep = (~dominated) & (cid >= 0) & np.isfinite(cdist)
    # first keep_n kept per row, in ascending-distance order
    rank = np.where(keep, np.arange(c)[None, :], c + 1)
    order = np.argsort(rank, axis=1, kind="stable")[:, :keep_n]
    sel = np.take_along_axis(cid, order, axis=1)
    selkeep = np.take_along_axis(keep, order, axis=1)
    return np.where(selkeep, sel, -1)


def build_graph(vectors: np.ndarray, bitmaps: np.ndarray, universe: int,
                r: int = 32, alpha: float = 1.2, seed: int = 0,
                n_cand: int = 64, block: int = 256,
                n_random_edges: int = 2) -> VamanaGraph:
    n, d = vectors.shape
    rng = np.random.default_rng(seed)
    norms = (vectors ** 2).sum(1).astype(np.float32)

    nlist = max(4, int(np.sqrt(n)))
    avg_list = max(8, n // nlist)
    ivf = ivf_mod.build_ivf(vectors, nlist, seed=seed, max_list_cap=3 * avg_list)
    assign = ivf_mod.assign_to_centroids(vectors, ivf.centroids)
    cd = ivf.centroid_norms[None, :] - 2.0 * ivf.centroids @ ivf.centroids.T
    near_clusters = np.argsort(cd, axis=1)[:, :3]               # [nlist, 3]

    c = min(n_cand, n - 1)
    neighbors = np.full((n, r), -1, dtype=np.int32)
    for s in range(0, n, block):
        e = min(s + block, n)
        b = e - s
        pool = ivf.lists[near_clusters[assign[s:e]]].reshape(b, -1)   # [B, P]
        rand = rng.integers(0, n, size=(b, 8)).astype(np.int32)
        pool = np.concatenate([pool, rand], axis=1)
        self_col = np.arange(s, e)[:, None]
        pool = np.where(pool == self_col, -1, pool)

        pv = vectors[np.maximum(pool, 0)]                             # [B, P, d]
        dq = norms[np.maximum(pool, 0)] - 2.0 * np.einsum(
            "bd,bpd->bp", vectors[s:e], pv, optimize=True)
        dq = np.where(pool < 0, np.inf, dq)

        top = np.argsort(dq, axis=1, kind="stable")[:, :c]            # [B, C]
        cid = np.take_along_axis(pool, top, axis=1)                   # [B, C]
        cdist = np.take_along_axis(dq, top, axis=1)                   # [B, C]
        sel = occlusion_prune(cid, cdist, vectors, norms, alpha,
                              max(r - n_random_edges, 1))
        neighbors[s:e, :sel.shape[1]] = sel
        # random long-range edges for connectivity
        if n_random_edges > 0:
            neighbors[s:e, -n_random_edges:] = rng.integers(
                0, n, size=(b, n_random_edges))

    medoid = int(np.argmin(norms - 2.0 * vectors @ vectors.mean(0)))

    # per-label entry points: the member vector nearest the label-subset mean
    label_entry = np.full(universe, -1, dtype=np.int32)
    for l in range(universe):
        word, bit = l >> 5, np.uint32(1) << np.uint32(l & 31)
        idx = np.nonzero((bitmaps[:, word] & bit) != 0)[0]
        if idx.size:
            sub_mean = vectors[idx].mean(0)
            label_entry[l] = int(idx[np.argmin(
                norms[idx] - 2.0 * vectors[idx] @ sub_mean)])
    return VamanaGraph(neighbors=neighbors, medoid=medoid, label_entry=label_entry)


@partial(jax.jit, static_argnames=("l_search", "iters"))
def beam_search(qvecs, seeds, neighbors, vectors, norms, *,
                l_search: int, iters: int):
    """Batched best-first graph search.

    qvecs [Q, d]; seeds [Q, S] int32 (−1 pad). Returns pool ids/dists
    [Q, L] sorted ascending by distance (−1/inf padding) — the caller
    applies predicate eligibility and takes the final top-k.
    """
    q, _ = qvecs.shape
    s = seeds.shape[1]
    L = l_search

    seed_vecs = vectors[jnp.maximum(seeds, 0)]                     # [Q,S,d]
    seed_d = norms[jnp.maximum(seeds, 0)] - 2.0 * jnp.einsum(
        "qd,qsd->qs", qvecs, seed_vecs)
    seed_d = jnp.where(seeds < 0, INF, seed_d)

    pool_ids = jnp.full((q, L), -1, dtype=jnp.int32)
    pool_d = jnp.full((q, L), INF)
    pool_ids = pool_ids.at[:, :min(s, L)].set(seeds[:, :min(s, L)])
    pool_d = pool_d.at[:, :min(s, L)].set(seed_d[:, :min(s, L)])
    expanded = jnp.zeros((q, L), dtype=bool)

    def body(_, state):
        pool_ids, pool_d, expanded = state
        sel_d = jnp.where(expanded | (pool_ids < 0), INF, pool_d)
        best = jnp.argmin(sel_d, axis=1)                            # [Q]
        best_id = jnp.take_along_axis(pool_ids, best[:, None], axis=1)[:, 0]
        alive = jnp.isfinite(jnp.min(sel_d, axis=1))
        expanded = expanded.at[jnp.arange(q), best].set(
            expanded[jnp.arange(q), best] | alive)

        nbrs = neighbors[jnp.maximum(best_id, 0)]                   # [Q,R]
        nbrs = jnp.where(alive[:, None] & (nbrs >= 0), nbrs, -1)
        nvec = vectors[jnp.maximum(nbrs, 0)]                        # [Q,R,d]
        nd = norms[jnp.maximum(nbrs, 0)] - 2.0 * jnp.einsum(
            "qd,qrd->qr", qvecs, nvec)
        nd = jnp.where(nbrs < 0, INF, nd)

        all_ids = jnp.concatenate([pool_ids, nbrs], axis=1)
        all_d = jnp.concatenate([pool_d, nd], axis=1)
        all_exp = jnp.concatenate([expanded, jnp.zeros_like(nbrs, dtype=bool)], axis=1)
        order = jnp.argsort(all_d, axis=1, stable=True)
        all_ids = jnp.take_along_axis(all_ids, order, axis=1)
        all_d = jnp.take_along_axis(all_d, order, axis=1)
        all_exp = jnp.take_along_axis(all_exp, order, axis=1)
        dup = jnp.concatenate(
            [jnp.zeros((q, 1), bool),
             (all_ids[:, 1:] == all_ids[:, :-1]) & (all_ids[:, 1:] >= 0)], axis=1)
        # Note on flags: the stable sort keeps pool entries (which carry the
        # correct expanded flag) ahead of same-distance new neighbours, so
        # the surviving first occurrence always has the right flag.
        all_d = jnp.where(dup, INF, all_d)
        all_ids = jnp.where(dup, -1, all_ids)
        order2 = jnp.argsort(all_d, axis=1, stable=True)
        all_ids = jnp.take_along_axis(all_ids, order2, axis=1)
        all_d = jnp.take_along_axis(all_d, order2, axis=1)
        all_exp = jnp.take_along_axis(all_exp, order2, axis=1)
        return (all_ids[:, :L], all_d[:, :L], all_exp[:, :L])

    pool_ids, pool_d, expanded = jax.lax.fori_loop(
        0, iters, body, (pool_ids, pool_d, expanded))
    return pool_ids, pool_d


def graft_graph(old: VamanaGraph, vectors: np.ndarray, bitmaps: np.ndarray,
                universe: int, old_to_new: np.ndarray, new_rows: np.ndarray,
                r: int = 32, alpha: float = 1.2, seed: int = 0,
                n_cand: int = 64, n_random_edges: int = 2) -> VamanaGraph:
    """Graft a compacted dataset onto an existing graph (FreshDiskANN-style
    StreamingMerge) instead of rebuilding it.

    Surviving rows keep their pruned edge lists with targets remapped
    through `old_to_new`; rows that lost a target compact their
    remaining edges leftward in order, while untouched rows keep their
    slot layout bit-for-bit (so an identity remap reproduces the old
    graph exactly). Each new row (`new_rows`, ids in the *new*
    dataset) finds its edge pool by beam-searching the surviving graph
    from the medoid — O(L·R·d) per row, independent of base size — plus
    its nearest other new rows, then runs the same α-occlusion prune as
    the offline build; its selected edges are back-inserted into the
    targets' free (or farthest, if closer) slots so the new rows are
    reachable. Label entry points recompute only for labels whose old
    entry died; surviving entries are kept as-is (entry points only need
    to be good seeds, not optimal ones). Deterministic for fixed inputs.
    """
    n = vectors.shape[0]
    rng = np.random.default_rng(seed)
    norms = (vectors ** 2).sum(1).astype(np.float32)
    new_rows = np.asarray(new_rows, dtype=np.int64)
    rr = old.neighbors.shape[1]

    # 1. survivors: remap edge targets, compact dropped slots leftward
    neighbors = np.full((n, rr), -1, dtype=np.int32)
    surv_old = np.nonzero(old_to_new >= 0)[0]
    if surv_old.size:
        dst = old_to_new[surv_old]
        nb = old.neighbors[surv_old].astype(np.int64)
        nb_new = np.where(nb >= 0, old_to_new[np.maximum(nb, 0)],
                          -1).astype(np.int32)
        # compact only rows that actually lost a target: untouched rows
        # keep their slot layout bit-for-bit (an identity remap must
        # reproduce the old graph exactly, interior padding included)
        died = (nb >= 0) & (nb_new < 0)
        need = died.any(axis=1)
        if need.any():
            order = np.argsort(nb_new[need] < 0, axis=1, kind="stable")
            nb_new[need] = np.take_along_axis(nb_new[need], order, axis=1)
        neighbors[dst] = nb_new

    # 2. medoid: keep if it survived, else recompute (one matvec)
    if 0 <= old.medoid < old_to_new.shape[0] and old_to_new[old.medoid] >= 0:
        medoid = int(old_to_new[old.medoid])
    else:
        medoid = int(np.argmin(norms - 2.0 * vectors @ vectors.mean(0)))

    # 3. new rows: pool = beam search over the survivor graph + nearest
    #    other new rows, then the shared occlusion prune
    if new_rows.size:
        b = len(new_rows)
        nv = vectors[new_rows]
        seeds = np.full((b, 4), -1, dtype=np.int32)
        seeds[:, 0] = medoid
        if surv_old.size:
            seeds[:, 1:] = old_to_new[surv_old][
                rng.integers(0, surv_old.size, size=(b, 3))]
        L = max(n_cand, rr + 1)
        pool_ids, pool_d = beam_search(
            jnp.asarray(nv), jnp.asarray(seeds), jnp.asarray(neighbors),
            jnp.asarray(vectors), jnp.asarray(norms),
            l_search=L, iters=L // 2)
        pool_ids = np.asarray(pool_ids)
        pool_d = np.asarray(pool_d).astype(np.float32)
        if b > 1:
            dn = norms[new_rows][None, :] - 2.0 * (nv @ nv.T)
            np.fill_diagonal(dn, np.inf)
            t = min(16, b - 1)
            nn_idx = np.argsort(dn, axis=1, kind="stable")[:, :t]
            pool_ids = np.concatenate(
                [pool_ids, new_rows[nn_idx].astype(np.int32)], axis=1)
            pool_d = np.concatenate(
                [pool_d, np.take_along_axis(dn, nn_idx, axis=1)
                 .astype(np.float32)], axis=1)
        merge = np.argsort(pool_d, axis=1, kind="stable")[:, :n_cand]
        cid = np.take_along_axis(pool_ids, merge, axis=1)
        cdist = np.take_along_axis(pool_d, merge, axis=1)
        cid = np.where(cid == new_rows[:, None], -1, cid)
        cdist = np.where(cid < 0, np.inf, cdist)
        sel = occlusion_prune(cid, cdist, vectors, norms, alpha,
                              max(rr - n_random_edges, 1))
        neighbors[new_rows, :sel.shape[1]] = sel
        if n_random_edges > 0:
            neighbors[new_rows, rr - n_random_edges:] = rng.integers(
                0, n, size=(b, n_random_edges))

        # reverse edges: make new rows reachable from their targets
        for i, u in enumerate(new_rows):
            for v in sel[i]:
                if v < 0 or v == u:
                    continue
                row = neighbors[v]
                if (row == u).any():
                    continue
                free = np.nonzero(row < 0)[0]
                if free.size:
                    row[free[0]] = u
                else:
                    dv = norms[row] - 2.0 * vectors[v] @ vectors[row].T
                    w = int(np.argmax(dv))
                    if float(norms[u] - 2.0 * vectors[v] @ vectors[u]) < dv[w]:
                        row[w] = u

    # 4. label entries: carry survivors, recompute orphaned labels only
    carried = np.where(old.label_entry >= 0,
                       old_to_new[np.maximum(old.label_entry, 0)], -1)
    label_entry = carried.astype(np.int32).copy()
    for l in range(universe):
        if carried[l] >= 0:
            continue
        word, bit = l >> 5, np.uint32(1) << np.uint32(l & 31)
        idx = np.nonzero((bitmaps[:, word] & bit) != 0)[0]
        if idx.size:
            sub_mean = vectors[idx].mean(0)
            label_entry[l] = int(idx[np.argmin(
                norms[idx] - 2.0 * vectors[idx] @ sub_mean)])
    return VamanaGraph(neighbors=neighbors, medoid=medoid,
                       label_entry=label_entry)
