"""Sieve — the SIEVE analogue (workload-specialised collection of indexes).

SIEVE pre-builds sub-indexes for the filter patterns a historical workload
hits most. Our TPU-native collection is a set of **materialised posting
lists** for the `n_lists` most frequent labels (dense padded rows):

* OR      — if every query label is materialised, the candidate set is the
            concatenation of its posting rows (recall 1 unless a row was
            truncated by `list_cap`);
* AND/EQ  — scan the *shortest* materialised posting row among the query's
            labels, verifying the full predicate per candidate (classic
            inverted-index intersection);
* miss    — fall back to Post-filter on a shared global IVF.

`index_budget`/`hist_pct` (paper Table 3) map to the materialised-label
fraction and `list_cap`; `ef_search` maps to the fallback k′.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann import engine, topk
from repro.ann.dataset import ANNDataset
from repro.ann.ivf import IVFIndex, build_ivf
from repro.ann.methods.postfilter import _search as _post_search
from repro.ann.predicates import Predicate


@partial(jax.jit, static_argnames=("k", "verify"))
def _scan_rows(qvecs, qbms, pred_idx, rows, vectors, norms, bitmaps,
               *, k: int, verify: bool):
    """rows: [Q, C] candidate ids (−1 pad); optionally verify predicate."""
    cvec = vectors[jnp.maximum(rows, 0)]
    cn = norms[jnp.maximum(rows, 0)]
    d = topk.score_candidates(qvecs, cvec, cn)
    valid = rows >= 0
    if verify:
        cbm = bitmaps[jnp.maximum(rows, 0)]
        valid &= engine.mask_cand(cbm, qbms, pred_idx)
    return topk.topk_ids(d, rows, k, valid=valid, dedup=True)


class Sieve(engine.Method):
    name = "sieve"

    def param_settings(self):
        return [
            engine.ps("b1", {"hist_pct": 0.25, "list_cap": 1024},
                      {"ef_search": 50}),
            engine.ps("b2", {"hist_pct": 0.5, "list_cap": 4096},
                      {"ef_search": 200}),
            engine.ps("b3", {"hist_pct": 1.0, "list_cap": 16384},
                      {"ef_search": 800}),
        ]

    def build(self, ds: ANNDataset, build_params: dict):
        hist_pct = float(build_params.get("hist_pct", 0.5))
        list_cap = int(build_params.get("list_cap", 4096))
        # label frequency from group table (the "historical workload" proxy:
        # query labels follow base-label popularity)
        freq = np.zeros(ds.universe, dtype=np.int64)
        members: dict[int, list[int]] = {}
        for g in range(ds.n_groups):
            s, l = int(ds.group_start[g]), int(ds.group_size[g])
            from repro.ann.labels import unpack_one
            for lab in unpack_one(ds.group_bitmaps[g]):
                freq[lab] += l
                members.setdefault(lab, []).extend(range(s, s + l))
        n_mat = max(1, int(np.ceil(hist_pct * ds.universe)))
        mat_labels = np.argsort(-freq, kind="stable")[:n_mat]
        mat_labels = [int(l) for l in mat_labels if freq[l] > 0]
        cap = min(list_cap, max((len(members[l]) for l in mat_labels), default=1))
        rows = np.full((max(len(mat_labels), 1), cap), -1, dtype=np.int32)
        truncated = np.zeros(max(len(mat_labels), 1), dtype=bool)
        row_of = {}
        for r, l in enumerate(mat_labels):
            ids = members[l][:cap]
            rows[r, :len(ids)] = ids
            truncated[r] = len(members[l]) > cap
            row_of[l] = r
        ivf = build_ivf(ds.vectors, 128, seed=29)
        return {"rows": rows, "row_of": row_of, "row_len":
                np.array([len(members[l]) for l in mat_labels] or [0]),
                "ivf": ivf, "cap": cap}

    def index_arrays(self, index) -> dict:
        labels = np.array(sorted(index["row_of"]), dtype=np.int64)
        ivf = index["ivf"]
        return {"rows": index["rows"], "row_len": index["row_len"],
                "cap": np.asarray(index["cap"], dtype=np.int64),
                "row_of_labels": labels,
                "row_of_rows": np.array(
                    [index["row_of"][int(l)] for l in labels],
                    dtype=np.int64),
                "ivf_centroids": ivf.centroids,
                "ivf_centroid_norms": ivf.centroid_norms,
                "ivf_lists": ivf.lists, "ivf_list_len": ivf.list_len}

    def index_from_arrays(self, ds, build_params: dict, arrays: dict):
        row_of = {int(l): int(r) for l, r in zip(arrays["row_of_labels"],
                                                 arrays["row_of_rows"])}
        ivf = IVFIndex(centroids=arrays["ivf_centroids"],
                       centroid_norms=arrays["ivf_centroid_norms"],
                       lists=arrays["ivf_lists"],
                       list_len=arrays["ivf_list_len"])
        return {"rows": arrays["rows"], "row_of": row_of,
                "row_len": arrays["row_len"], "ivf": ivf,
                "cap": int(arrays["cap"])}

    def search(self, fx, index, qvecs, qbms, pred: Predicate, k: int,
               search_params: dict):
        from repro.ann.labels import unpack_one

        dev = fx.device
        pred = Predicate(pred)
        pred_idx = jnp.int32(int(pred))
        nq = qvecs.shape[0]
        row_of = index["row_of"]
        rows_np = index["rows"]
        cap = index["cap"]

        # ---- host-side pattern resolution (the paper's sub-index pick) ----
        max_or = 8
        hit = np.zeros(nq, dtype=bool)
        sel_rows = np.full((nq, max_or), -1, dtype=np.int32)
        for qi in range(nq):
            labs = sorted(unpack_one(qbms[qi]))
            mat = [row_of[l] for l in labs if l in row_of]
            if pred == Predicate.OR:
                if len(mat) == len(labs) and 0 < len(labs) <= max_or:
                    hit[qi] = True
                    sel_rows[qi, :len(mat)] = mat
            else:  # AND / EQUALITY: shortest materialised posting row
                if mat:
                    lens = [index["row_len"][r] for r in mat]
                    hit[qi] = True
                    sel_rows[qi, 0] = mat[int(np.argmin(lens))]

        out = np.full((nq, k), -1, dtype=np.int32)
        out_d = np.full((nq, k), np.inf, dtype=np.float32)
        hit_idx = np.nonzero(hit)[0]
        miss_idx = np.nonzero(~hit)[0]

        if hit_idx.size:
            if pred == Predicate.OR:
                cand = rows_np[np.maximum(sel_rows[hit_idx], 0)]      # [H, max_or, cap]
                cand = np.where(sel_rows[hit_idx][:, :, None] >= 0, cand, -1)
                cand = cand.reshape(hit_idx.size, -1)
                verify = False        # union of exact posting rows: all valid
            else:
                cand = rows_np[sel_rows[hit_idx, 0]]                  # [H, cap]
                verify = True
            fn = lambda qv, qb, cd: _scan_rows(
                qv, qb, pred_idx, cd, dev.vectors, dev.norms, dev.bitmaps,
                k=k, verify=verify)
            chunk = max(8, min(engine.DEFAULT_QCHUNK,
                               (1 << 24) // max(1, cand.shape[1])))
            out[hit_idx], out_d[hit_idx] = engine.run_chunked(
                fn, hit_idx.size, qvecs[hit_idx], qbms[hit_idx], cand,
                chunk=chunk)

        if miss_idx.size:
            ivf = index["ivf"]
            kprime = int(search_params.get("ef_search", 200))
            fn = lambda qv, qb: _post_search(
                qv, qb, pred_idx, fx.as_device(ivf.centroids),
                fx.as_device(ivf.centroid_norms), fx.as_device(ivf.lists),
                dev.vectors, dev.norms, dev.bitmaps,
                nprobe=min(8, ivf.centroids.shape[0]), kprime=kprime, k=k)
            out[miss_idx], out_d[miss_idx] = engine.run_chunked(
                fn, miss_idx.size, qvecs[miss_idx], qbms[miss_idx])
        return out, out_d
