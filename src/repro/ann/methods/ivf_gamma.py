"""IVFGamma — the ACORN-γ analogue (hybrid search, predicate-agnostic).

ACORN-γ widens HNSW neighbourhoods γ-fold so that predicate-passing
reachability survives filtering, pruning failing nodes *during* traversal.
The TPU-native counterpart: probe γ× more IVF lists than the unfiltered
baseline would and apply the predicate mask **in-scan**, so every candidate
that reaches top-k already satisfies the filter. γ trades compute for
recall uniformly across predicate types.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann import engine, topk
from repro.ann.dataset import ANNDataset
from repro.ann.ivf import IVFIndex, build_ivf, graft_ivf
from repro.ann.predicates import Predicate


@partial(jax.jit, static_argnames=("nprobe", "k"))
def _search(qvecs, qbms, pred_idx, centroids, cnorms, lists,
            vectors, norms, bitmaps, *, nprobe: int, k: int):
    nq = qvecs.shape[0]
    cd = topk.score_all(qvecs, centroids, cnorms)
    _, probe = jax.lax.top_k(-cd, nprobe)
    cand = lists[probe].reshape(nq, -1)                        # [Q, C]
    cvec = vectors[jnp.maximum(cand, 0)]
    cn = norms[jnp.maximum(cand, 0)]
    d = topk.score_candidates(qvecs, cvec, cn)
    cbm = bitmaps[jnp.maximum(cand, 0)]                        # [Q, C, W]
    ok = engine.mask_cand(cbm, qbms, pred_idx) & (cand >= 0)
    return topk.topk_ids(d, cand, k, valid=ok)


class IVFGamma(engine.Method):
    name = "ivf_gamma"

    def param_settings(self):
        # ACORN-γ Table 3: γ ∈ {1,4,8,...} — base nprobe 4, probe 4γ lists.
        return [
            engine.ps("g1", {"nlist": 128}, {"gamma": 1}),
            engine.ps("g4", {"nlist": 128}, {"gamma": 4}),
            engine.ps("g8", {"nlist": 128}, {"gamma": 8}),
        ]

    def build(self, ds: ANNDataset, build_params: dict) -> IVFIndex:
        return build_ivf(ds.vectors, int(build_params.get("nlist", 128)),
                         seed=13)

    def index_arrays(self, index: IVFIndex) -> dict:
        return {"centroids": index.centroids,
                "centroid_norms": index.centroid_norms,
                "lists": index.lists, "list_len": index.list_len}

    def index_from_arrays(self, ds: ANNDataset, build_params: dict,
                          arrays: dict) -> IVFIndex:
        return IVFIndex(centroids=arrays["centroids"],
                        centroid_norms=arrays["centroid_norms"],
                        lists=arrays["lists"],
                        list_len=arrays["list_len"])

    def graft_index(self, new_ds: ANNDataset, old_index: IVFIndex,
                    old_ds: ANNDataset, old_to_new, new_rows, build_params):
        if old_index.centroids.shape[0] == 0 or new_ds.n == 0:
            return None
        return graft_ivf(old_index, new_ds.vectors, old_to_new)

    def search(self, fx, index: IVFIndex, qvecs, qbms, pred: Predicate,
               k: int, search_params: dict):
        dev = fx.device
        pred_idx = jnp.int32(int(Predicate(pred)))
        nprobe = min(4 * int(search_params["gamma"]), index.centroids.shape[0])
        cent = fx.as_device(index.centroids)
        cn = fx.as_device(index.centroid_norms)
        lists = fx.as_device(index.lists)
        fn = lambda qv, qb: _search(
            qv, qb, pred_idx, cent, cn, lists, dev.vectors, dev.norms,
            dev.bitmaps, nprobe=nprobe, k=k)
        chunk = max(8, min(engine.DEFAULT_QCHUNK,
                           (1 << 23) // max(1, nprobe * index.lists.shape[1])))
        return engine.run_chunked(fn, qvecs.shape[0], qvecs, qbms, chunk=chunk)
