"""The six filtered-ANN methods (TPU-native adaptations — DESIGN.md §2)."""

from repro.ann.methods.prefilter import PreFilter
from repro.ann.methods.postfilter import PostFilter
from repro.ann.methods.labelnav import LabelNav
from repro.ann.methods.sieve import Sieve
from repro.ann.methods.ivf_gamma import IVFGamma
from repro.ann.methods.fvamana import FVamana

# Candidate pool the router selects among — mirrors the paper's five
# (UNG, Post-filter, SIEVE, ACORN-γ, FilteredVamana).
CANDIDATE_METHODS = {
    "labelnav": LabelNav(),       # UNG analogue
    "postfilter": PostFilter(),   # Post-filter analogue
    "sieve": Sieve(),             # SIEVE analogue
    "ivf_gamma": IVFGamma(),      # ACORN-γ analogue
    "fvamana": FVamana(),         # FilteredVamana analogue
}

ALL_METHODS = {"prefilter": PreFilter(), **CANDIDATE_METHODS}

# paper-name aliases for reporting
PAPER_NAMES = {
    "prefilter": "Pre-filter",
    "postfilter": "Post-filter",
    "labelnav": "UNG",
    "sieve": "SIEVE",
    "ivf_gamma": "ACORN-g",
    "fvamana": "FilteredVamana",
}
