"""The six filtered-ANN methods (TPU-native adaptations — DESIGN.md §2).

Importing this package registers the built-ins in the default
`repro.ann.registry`; `CANDIDATE_METHODS` / `ALL_METHODS` are live
registry views, so `register_method()` extends the pool without any
edit here.
"""

from repro.ann import registry as _registry
from repro.ann.methods.prefilter import PreFilter
from repro.ann.methods.postfilter import PostFilter
from repro.ann.methods.labelnav import LabelNav
from repro.ann.methods.sieve import Sieve
from repro.ann.methods.ivf_gamma import IVFGamma
from repro.ann.methods.fvamana import FVamana

# Candidate pool the router selects among — mirrors the paper's five
# (UNG, Post-filter, SIEVE, ACORN-γ, FilteredVamana). Pre-filter is the
# exact non-candidate baseline.
_BUILTINS = (
    (PreFilter(), False),
    (LabelNav(), True),       # UNG analogue
    (PostFilter(), True),     # Post-filter analogue
    (Sieve(), True),          # SIEVE analogue
    (IVFGamma(), True),       # ACORN-γ analogue
    (FVamana(), True),        # FilteredVamana analogue
)
for _m, _cand in _BUILTINS:
    if _m.name not in _registry._DEFAULT:
        _registry._DEFAULT.register(_m, candidate=_cand)

CANDIDATE_METHODS = _registry._DEFAULT.view(candidates_only=True)
ALL_METHODS = _registry._DEFAULT.view()

# paper-name aliases for reporting
PAPER_NAMES = {
    "prefilter": "Pre-filter",
    "postfilter": "Post-filter",
    "labelnav": "UNG",
    "sieve": "SIEVE",
    "ivf_gamma": "ACORN-g",
    "fvamana": "FilteredVamana",
}
