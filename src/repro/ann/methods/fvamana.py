"""FVamana — the FilteredVamana analogue (hybrid graph search).

Offline: α-pruned Vamana-style graph + per-label entry points (the
label-aware part of FilteredVamana's build). Online: fixed-iteration
batched best-first search seeded at the medoid plus the query labels'
entry points; traversal routes through predicate-failing nodes (they keep
the graph navigable) but only predicate-passing pool entries are eligible
for the final top-k — label-aware pruning at result granularity.
`L_search` is the paper's quality knob.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.ann import engine, graph, topk
from repro.ann.dataset import ANNDataset
from repro.ann.labels import unpack_one
from repro.ann.predicates import Predicate


class FVamana(engine.Method):
    name = "fvamana"

    MAX_SEEDS = 5

    def param_settings(self):
        # FilteredVamana Table 3: R ∈ {32,64}, L_search ∈ {16..128}
        return [
            engine.ps("L16", {"r": 32}, {"l_search": 16}),
            engine.ps("L32", {"r": 32}, {"l_search": 32}),
            engine.ps("L64", {"r": 32}, {"l_search": 64}),
            engine.ps("L128", {"r": 32}, {"l_search": 128}),
        ]

    def build(self, ds: ANNDataset, build_params: dict) -> graph.VamanaGraph:
        return graph.build_graph(ds.vectors, ds.bitmaps, ds.universe,
                                 r=int(build_params.get("r", 32)), seed=17)

    def index_arrays(self, index: graph.VamanaGraph) -> dict:
        return {"neighbors": index.neighbors,
                "medoid": np.asarray(index.medoid, dtype=np.int64),
                "label_entry": index.label_entry}

    def index_from_arrays(self, ds: ANNDataset, build_params: dict,
                          arrays: dict) -> graph.VamanaGraph:
        return graph.VamanaGraph(neighbors=arrays["neighbors"],
                                 medoid=int(arrays["medoid"]),
                                 label_entry=arrays["label_entry"])

    def graft_index(self, new_ds: ANNDataset, old_index: graph.VamanaGraph,
                    old_ds: ANNDataset, old_to_new, new_rows, build_params):
        n_surv = int((old_to_new >= 0).sum())
        # grafting pays off only while the surviving graph dominates; a
        # mostly-new dataset searches better on a fresh build
        if n_surv == 0 or new_ds.n == 0 or len(new_rows) > n_surv:
            return None
        return graph.graft_graph(old_index, new_ds.vectors, new_ds.bitmaps,
                                 new_ds.universe, old_to_new, new_rows,
                                 r=int(build_params.get("r", 32)), seed=17)

    def search(self, fx, index: graph.VamanaGraph, qvecs, qbms,
               pred: Predicate, k: int, search_params: dict):
        dev = fx.device
        pred_idx = jnp.int32(int(Predicate(pred)))
        l_search = int(search_params["l_search"])
        nq = qvecs.shape[0]

        # host-side seed assembly: medoid + query-label entry points
        seeds = np.full((nq, self.MAX_SEEDS), -1, dtype=np.int32)
        seeds[:, 0] = index.medoid
        for qi in range(nq):
            labs = sorted(unpack_one(qbms[qi]))[: self.MAX_SEEDS - 1]
            for j, l in enumerate(labs):
                seeds[qi, 1 + j] = index.label_entry[l]

        nbrs = fx.as_device(index.neighbors)

        def fn(qv, qb, sd):
            pool_ids, pool_d = graph.beam_search(
                qv, sd, nbrs, dev.vectors, dev.norms,
                l_search=l_search, iters=l_search)
            cbm = dev.bitmaps[jnp.maximum(pool_ids, 0)]
            ok = engine.mask_cand(cbm, qb, pred_idx) & (pool_ids >= 0)
            return topk.topk_ids(pool_d, pool_ids, k, valid=ok)

        return engine.run_chunked(fn, nq, qvecs, qbms, seeds)
