"""Post-filter: search-then-filter on an IVF index.

Retrieve the top-k′ (k′ ≫ k) unfiltered candidates from `nprobe` IVF lists
(MXU distance blocks over gathered rows), then verify the predicate on
those k′ and keep the best k valid ones. Mirrors Post-filter HNSW/IVFPQ:
cheap, but recall collapses when selectivity ≪ k/k′ (the k′ cap).
`ef`≈k′ is the quality knob the router tunes.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann import engine, topk
from repro.ann.dataset import ANNDataset
from repro.ann.ivf import IVFIndex, build_ivf, graft_ivf
from repro.ann.predicates import Predicate


@partial(jax.jit, static_argnames=("nprobe", "kprime", "k"))
def _search(qvecs, qbms, pred_idx, centroids, cnorms, lists,
            vectors, norms, bitmaps, *, nprobe: int, kprime: int, k: int):
    nq = qvecs.shape[0]
    cd = topk.score_all(qvecs, centroids, cnorms)              # [Q, nlist]
    _, probe = jax.lax.top_k(-cd, nprobe)                      # [Q, nprobe]
    cand = lists[probe].reshape(nq, -1)                        # [Q, C]
    cvec = vectors[jnp.maximum(cand, 0)]                       # [Q, C, d]
    cn = norms[jnp.maximum(cand, 0)]
    d = topk.score_candidates(qvecs, cvec, cn)
    d = jnp.where(cand < 0, topk.INF, d)
    # stage 1: unfiltered top-k' (dedup: ivf lists are disjoint, no dups)
    kp = min(kprime, d.shape[1])
    negd, idx = jax.lax.top_k(-d, kp)                          # [Q, k']
    cid = jnp.take_along_axis(cand, idx, axis=1)
    cid = jnp.where(jnp.isinf(negd), -1, cid)
    # stage 2: verify predicate on the k' survivors only
    cbm = bitmaps[jnp.maximum(cid, 0)]                         # [Q, k', W]
    ok = engine.mask_cand(cbm, qbms, pred_idx) & (cid >= 0)
    return topk.topk_ids(-negd, cid, k, valid=ok)


class PostFilter(engine.Method):
    name = "postfilter"

    def param_settings(self):
        # paper Table 3: M/efc (build), ef (search). Our knobs: nlist (build),
        # nprobe + kprime≈ef (search).
        return [
            engine.ps("ef200", {"nlist": 128}, {"nprobe": 8, "kprime": 200}),
            engine.ps("ef800", {"nlist": 128}, {"nprobe": 16, "kprime": 800}),
            engine.ps("ef2000", {"nlist": 128}, {"nprobe": 32, "kprime": 2000}),
        ]

    def build(self, ds: ANNDataset, build_params: dict) -> IVFIndex:
        return build_ivf(ds.vectors, int(build_params.get("nlist", 128)),
                         seed=13)

    def index_arrays(self, index: IVFIndex) -> dict:
        return {"centroids": index.centroids,
                "centroid_norms": index.centroid_norms,
                "lists": index.lists, "list_len": index.list_len}

    def index_from_arrays(self, ds: ANNDataset, build_params: dict,
                          arrays: dict) -> IVFIndex:
        return IVFIndex(centroids=arrays["centroids"],
                        centroid_norms=arrays["centroid_norms"],
                        lists=arrays["lists"],
                        list_len=arrays["list_len"])

    def graft_index(self, new_ds: ANNDataset, old_index: IVFIndex,
                    old_ds: ANNDataset, old_to_new, new_rows, build_params):
        if old_index.centroids.shape[0] == 0 or new_ds.n == 0:
            return None
        return graft_ivf(old_index, new_ds.vectors, old_to_new)

    def search(self, fx, index: IVFIndex, qvecs, qbms, pred: Predicate,
               k: int, search_params: dict):
        dev = fx.device
        pred_idx = jnp.int32(int(Predicate(pred)))
        nprobe = int(search_params["nprobe"])
        kprime = int(search_params["kprime"])
        cent = fx.as_device(index.centroids)
        cn = fx.as_device(index.centroid_norms)
        lists = fx.as_device(index.lists)
        nprobe = min(nprobe, index.centroids.shape[0])
        fn = lambda qv, qb: _search(
            qv, qb, pred_idx, cent, cn, lists, dev.vectors, dev.norms,
            dev.bitmaps, nprobe=nprobe, kprime=kprime, k=k)
        chunk = max(8, min(engine.DEFAULT_QCHUNK,
                           (1 << 24) // max(1, nprobe * index.lists.shape[1])))
        return engine.run_chunked(fn, qvecs.shape[0], qvecs, qbms, chunk=chunk)
