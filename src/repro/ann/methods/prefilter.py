"""Pre-filter: exact masked brute-force scan (recall = 1 by construction).

The compute hot-spot of the whole engine — on TPU this is the Pallas
`masked_topk` kernel (repro/kernels); the jnp path below is the
numerically identical reference used on CPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann import engine, topk
from repro.ann.dataset import ANNDataset
from repro.ann.predicates import Predicate


@partial(jax.jit, static_argnames=("k",))
def _search(qvecs, qbms, pred_idx, vectors, norms, bitmaps, *, k: int):
    scores = topk.score_all(qvecs, vectors, norms)            # [Q, N]
    mask = engine.mask_shared(bitmaps, qbms, pred_idx)        # [Q, N]
    scores = jnp.where(mask, scores, topk.INF)
    neg, idx = jax.lax.top_k(-scores, k)
    return jnp.where(jnp.isinf(neg), -1, idx).astype(jnp.int32)


class PreFilter(engine.Method):
    name = "prefilter"

    def param_settings(self):
        return [engine.ps("exact")]

    def build(self, ds: ANNDataset, build_params: dict):
        return None

    def search(self, ds, index, qvecs, qbms, pred: Predicate, k: int,
               search_params: dict) -> np.ndarray:
        dev = engine.device_data(ds)
        pred_idx = jnp.int32(int(Predicate(pred)))
        fn = lambda qv, qb: _search(qv, qb, pred_idx, dev.vectors,
                                    dev.norms, dev.bitmaps, k=k)
        return engine.run_chunked(fn, qvecs.shape[0], qvecs, qbms)
