"""Pre-filter: exact masked brute-force scan (recall = 1 by construction).

The compute hot-spot of the whole engine — on TPU backends the search is
routed through the Pallas `ops.masked_topk` kernel (VMEM-accumulated,
final [Q, k] emitted directly); the jnp path below is the numerically
identical CPU/parity reference. `PreFilter(use_kernel=True)` forces the
kernel (interpret mode off-TPU) for parity testing.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann import engine, topk
from repro.ann.dataset import ANNDataset
from repro.ann.predicates import Predicate


@partial(jax.jit, static_argnames=("k",))
def _search(qvecs, qbms, pred_idx, vectors, norms, bitmaps, *, k: int):
    scores = topk.score_all(qvecs, vectors, norms)            # [Q, N]
    mask = engine.mask_shared(bitmaps, qbms, pred_idx)        # [Q, N]
    scores = jnp.where(mask, scores, topk.INF)
    neg, idx = jax.lax.top_k(-scores, k)
    ids = jnp.where(jnp.isinf(neg), -1, idx).astype(jnp.int32)
    return ids, -neg


class PreFilter(engine.Method):
    name = "prefilter"

    def __init__(self, use_kernel: bool | None = None):
        # None = auto (kernel on TPU, jnp reference elsewhere)
        self.use_kernel = use_kernel

    def param_settings(self):
        return [engine.ps("exact")]

    def build(self, ds: ANNDataset, build_params: dict):
        return None

    def index_arrays(self, index) -> dict:
        return {}          # stateless build: persists as nothing

    def index_from_arrays(self, ds: ANNDataset, build_params: dict,
                          arrays: dict):
        return None

    def search(self, fx, index, qvecs, qbms, pred: Predicate, k: int,
               search_params: dict):
        dev = fx.device
        p = int(Predicate(pred))
        use_kernel = (jax.default_backend() == "tpu"
                      if self.use_kernel is None else self.use_kernel)
        if use_kernel:
            from repro.kernels import ops

            fn = lambda qv, qb: ops.masked_topk(
                qv, qb, dev.vectors, dev.norms, dev.bitmaps, pred=p, k=k)
            return engine.run_chunked(fn, qvecs.shape[0], qvecs, qbms)
        pred_idx = jnp.int32(p)
        fn = lambda qv, qb: _search(qv, qb, pred_idx, dev.vectors,
                                    dev.norms, dev.bitmaps, k=k)
        return engine.run_chunked(fn, qvecs.shape[0], qvecs, qbms)
