"""LabelNav — the UNG analogue (filter-then-search).

UNG builds per-label-set sub-graphs linked by a label navigating graph.
Our TPU-native layout: vectors are stored **group-sorted** (one contiguous
extent per unique label set); searching is

* Equality — O(1) host hash lookup of the query's group, then one fused
  distance scan over that extent (recall = 1, exactly UNG's sweet spot);
* AND/OR — predicate over the [G, W] *group* bitmaps picks qualifying
  groups, a group-centroid distance ranks them ("navigation"), and the
  nearest `group_cap` groups are scanned up to `per_group_cap` members
  each. Recall degrades when many groups qualify (OR) — UNG's documented
  weakness.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann import engine, topk
from repro.ann.dataset import ANNDataset
from repro.ann.predicates import Predicate


@partial(jax.jit, static_argnames=("maxg", "k"))
def _search_eq(qvecs, qgroup, group_start, group_size, vectors, norms,
               *, maxg: int, k: int):
    """Exact-match: scan the query's own group extent."""
    start = group_start[jnp.maximum(qgroup, 0)]                 # [Q]
    size = jnp.where(qgroup < 0, 0, group_size[jnp.maximum(qgroup, 0)])
    offs = jnp.arange(maxg, dtype=jnp.int32)[None, :]           # [1, maxg]
    cand = start[:, None] + offs                                # [Q, maxg]
    valid = offs < size[:, None]
    cand = jnp.where(valid, cand, -1)
    cvec = vectors[jnp.maximum(cand, 0)]
    cn = norms[jnp.maximum(cand, 0)]
    d = topk.score_candidates(qvecs, cvec, cn)
    return topk.topk_ids(d, cand, k)


@partial(jax.jit, static_argnames=("group_cap", "per_group_cap", "k"))
def _search_sub(qvecs, qbms, pred_idx, group_bitmaps, group_start, group_size,
                gcent, gcnorms, vectors, norms,
                *, group_cap: int, per_group_cap: int, k: int):
    """AND/OR: navigate to nearest qualifying groups, scan their extents."""
    nq = qvecs.shape[0]
    ok = engine.mask_shared(group_bitmaps, qbms, pred_idx)      # [Q, G]
    gscore = topk.score_all(qvecs, gcent, gcnorms)              # [Q, G]
    gscore = jnp.where(ok, gscore, topk.INF)
    neg, gsel = jax.lax.top_k(-gscore, group_cap)               # [Q, GC]
    gvalid = jnp.isfinite(neg)                                  # [Q, GC]
    start = group_start[gsel]                                   # [Q, GC]
    size = jnp.where(gvalid, group_size[gsel], 0)
    offs = jnp.arange(per_group_cap, dtype=jnp.int32)[None, None, :]
    cand = start[:, :, None] + offs                             # [Q, GC, PGC]
    valid = offs < size[:, :, None]
    cand = jnp.where(valid, cand, -1).reshape(nq, -1)
    cvec = vectors[jnp.maximum(cand, 0)]
    cn = norms[jnp.maximum(cand, 0)]
    d = topk.score_candidates(qvecs, cvec, cn)
    return topk.topk_ids(d, cand, k)


class LabelNav(engine.Method):
    name = "labelnav"

    def param_settings(self):
        # UNG Table 3: L_search ∈ {100,300,500} -> (group_cap, per_group_cap)
        return [
            engine.ps("L100", {}, {"group_cap": 4, "per_group_cap": 128}),
            engine.ps("L300", {}, {"group_cap": 16, "per_group_cap": 256}),
            engine.ps("L500", {}, {"group_cap": 64, "per_group_cap": 512}),
        ]

    def build(self, ds: ANNDataset, build_params: dict):
        return {"maxg": int(ds.group_size.max())}

    def index_arrays(self, index) -> dict:
        return {"maxg": np.asarray(index["maxg"], dtype=np.int64)}

    def index_from_arrays(self, ds: ANNDataset, build_params: dict,
                          arrays: dict):
        return {"maxg": int(arrays["maxg"])}

    def search(self, fx, index, qvecs, qbms, pred: Predicate, k: int,
               search_params: dict):
        ds = fx.ds
        dev = fx.device
        pred = Predicate(pred)
        nq = qvecs.shape[0]
        if pred == Predicate.EQUALITY:
            qgroup = np.asarray(
                [ds.group_id_of_bitmap(qbms[i]) for i in range(nq)],
                dtype=np.int32)
            maxg = max(8, index["maxg"])
            fn = lambda qv, qg: _search_eq(
                qv, qg, dev.group_start, dev.group_size, dev.vectors,
                dev.norms, maxg=maxg, k=k)
            chunk = max(8, min(engine.DEFAULT_QCHUNK, (1 << 24) // maxg))
            return engine.run_chunked(fn, nq, qvecs, qgroup, chunk=chunk)

        gc = min(int(search_params["group_cap"]), ds.n_groups)
        pgc = int(search_params["per_group_cap"])
        pred_idx = jnp.int32(int(pred))
        fn = lambda qv, qb: _search_sub(
            qv, qb, pred_idx, dev.group_bitmaps, dev.group_start,
            dev.group_size, dev.group_centroids, dev.group_cnorms,
            dev.vectors, dev.norms, group_cap=gc, per_group_cap=pgc, k=k)
        chunk = max(8, min(engine.DEFAULT_QCHUNK, (1 << 24) // (gc * pgc)))
        return engine.run_chunked(fn, nq, qvecs, qbms, chunk=chunk)
