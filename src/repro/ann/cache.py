"""`SemanticResultCache` — a query-result cache + admission layer in
front of the serving facade.

At millions-of-users traffic the query stream is heavily repetitive and
near-duplicate, so the fastest search is the one that never runs. The
cache fronts a `RouterService`/`ShardedRouterService` (or, with
`method=`, any bare handle exposing `search(batch, method, setting)`)
and serves two kinds of hits:

* **exact** — byte-identical (query vector, query bitmap, predicate, k).
  The hit path is a dict lookup plus a freshness check: it bypasses
  routing *and* search entirely and returns the cached `SearchResult`
  slice verbatim (ids, exact distances, stable keys) — bit-identical to
  a fresh search at the entry's pinned snapshot.
* **semantic** — a cached query under the *same* (bitmap, predicate, k)
  whose cosine similarity to the incoming vector clears `threshold`.
  The neighbour's (staleness-checked) result rows are re-scored against
  the incoming vector — exact squared-L2 recomputed from the row
  vectors, re-sorted — so distances are exact for the returned rows,
  but the row *set* is the neighbour's top-k: an approximation that is
  only as good as the threshold. `threshold=None` disables this path.
* **transfer** — when no same-bitmap neighbour clears the threshold, a
  cached query under a provably *looser* filter may still serve: OR
  with cached labels ⊇ the query's, AND with cached labels ⊆ the
  query's.  Served only if every valid cached row also satisfies the
  tighter query filter (packed-bitmap re-check per row), which makes
  the cached top-k exactly the query's top-k over its admissible rows.

The semantic lookup reuses our own `FilteredIndex` as the cache's
lookup structure: cached query vectors + bitmaps form a tiny
`ANNDataset` (rebuilt every `rebuild_every` insertions, linear-scan
tail in between) and the hit test is an EQUALITY-predicate `prefilter`
search over it — identical-bitmap nearest neighbours only, which is
exactly the set a same-predicate result can transfer to.

Staleness is not TTL-guesswork: live handles stamp every label they
write with a monotone clock (`_LabelClockMixin` in `repro.ann.live`),
and an entry recorded at clock `c` is served only while
`label_clock(entry labels) <= c` — upserts/deletes touching the
predicate's label set evict exactly the affected entries, writes to
disjoint labels don't. Compactions remap ids but never change the live
row set, so entries *survive* them: on a generation mismatch the hit
path re-resolves current ids through the stable keys (`rows_of`) and
re-sorts. Sealed handles report a constant clock and never go stale.
A TTL (`ttl_s`) caps entry age on top; `capacity` bounds the cache with
LRU eviction; `admit_after` is the admission doorkeeper (a key must
miss that many times before it is cached — keeps one-off queries from
churning the LRU).

Counters (hits/misses/evictions/insertions) surface through `stats()`
and, when a `TelemetrySink` is attached, through `sink.stats()
["counters"]` via `note()`. `AsyncBatchQueue` probes the cache before
batching (`probe_one`) and fills per-group on miss through the wrapped
`route`/`execute` pipeline.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict

import numpy as np

from repro.ann import ledger as ledger_mod
from repro.ann import trace
from repro.ann.dataset import ANNDataset
from repro.ann.index import FilteredIndex, QueryBatch, SearchResult
from repro.ann.predicates import Predicate

__all__ = ["SemanticResultCache"]


def _labels_of(bitmap: np.ndarray) -> np.ndarray:
    """int64 label indices set in one packed [W] uint32 bitmap."""
    bits = np.unpackbits(
        np.ascontiguousarray(bitmap, dtype=np.uint32).view(np.uint8),
        bitorder="little")
    return np.nonzero(bits)[0].astype(np.int64)


class _Entry:
    """One cached (query, predicate, k) -> result mapping."""

    __slots__ = ("vector", "vnorm", "bitmap", "labels", "pred", "k",
                 "clock", "generation", "ids", "distances", "keys",
                 "expires_at", "alive", "ekey")

    @property
    def nbytes(self) -> int:
        return (self.vector.nbytes + self.bitmap.nbytes
                + self.labels.nbytes + self.ids.nbytes
                + self.distances.nbytes + self.keys.nbytes)

    def __init__(self, vector, bitmap, pred, k, *, clock, generation,
                 ids, distances, keys, expires_at, ekey):
        self.vector = np.array(vector, dtype=np.float32, copy=True)
        self.vnorm = float(np.sqrt((self.vector.astype(np.float64)
                                    ** 2).sum()))
        self.bitmap = np.array(bitmap, dtype=np.uint32, copy=True)
        self.labels = _labels_of(self.bitmap)
        self.pred = Predicate(pred)
        self.k = int(k)
        self.clock = int(clock)
        self.generation = int(generation)
        self.ids = np.array(ids, dtype=np.int32, copy=True)
        self.distances = np.array(distances, dtype=np.float32, copy=True)
        self.keys = np.array(keys, dtype=np.int64, copy=True)
        self.expires_at = expires_at
        self.alive = True
        self.ekey = ekey


class _SimPart:
    """Per-(predicate, k) similarity lookup over the partition's cached
    query vectors: a `FilteredIndex` over the queries-so-far (rebuilt
    every `rebuild_every` insertions) plus a linear-scan tail for
    entries newer than the last rebuild."""

    def __init__(self, universe: int, name: str):
        self.universe = universe
        self.name = name
        self.fx: FilteredIndex | None = None
        self.built: list[_Entry] = []     # row i of fx.ds -> entry
        self.tail: list[_Entry] = []
        self.seq = 0

    def add(self, entry: _Entry, rebuild_every: int) -> None:
        self.tail.append(entry)
        if len(self.tail) >= max(int(rebuild_every), 1):
            self.rebuild()

    def rebuild(self) -> None:
        alive = [e for e in self.built + self.tail if e.alive]
        self.tail = []
        if self.fx is not None:
            self.fx.close()
            self.fx = None
        self.built = []
        if not alive:
            return
        vecs = np.stack([e.vector for e in alive])
        bms = np.stack([e.bitmap for e in alive])
        self.seq += 1
        ds, order = ANNDataset.from_packed(
            f"{self.name}/g{self.seq}", vecs, bms, self.universe,
            return_order=True)
        self.built = [alive[int(i)] for i in order]
        self.fx = FilteredIndex(ds)

    def candidates(self, vector: np.ndarray, bitmap: np.ndarray,
                   probe: int) -> list[_Entry]:
        """Cached entries with `bitmap` exactly equal to the query's,
        nearest-first from the built index, plus the whole tail."""
        out: list[_Entry] = []
        if self.fx is not None:
            kk = min(max(int(probe), 1), self.fx.ds.n)
            res = self.fx.search(
                QueryBatch(vector[None], bitmap[None],
                           Predicate.EQUALITY, kk), "prefilter")
            for rid in res.ids[0]:
                if rid >= 0:
                    out.append(self.built[int(rid)])
        bkey = bitmap.tobytes()
        out.extend(e for e in self.tail if e.bitmap.tobytes() == bkey)
        return out

    def entries(self) -> list[_Entry]:
        """Every alive entry in the partition (bitmap-agnostic scan —
        the subset/superset transfer probe's candidate pool)."""
        return ([e for e in self.built if e.alive]
                + [e for e in self.tail if e.alive])

    def close(self) -> None:
        if self.fx is not None:
            self.fx.close()
            self.fx = None
        self.built = []
        self.tail = []


class SemanticResultCache:
    """Result cache + admission layer over a routed service or a bare
    index handle.

    Args:
        service: a `RouterService`/`ShardedRouterService` (routed
            fill-on-miss; the cache then also exposes `route`/`execute`
            so `AsyncBatchQueue` keeps its two-stage pipeline), or any
            handle with `search(batch, method, setting)` when `method=`
            is given (router-less serving).
        threshold: cosine similarity a cached same-bitmap query must
            clear for a semantic hit. None disables semantic hits
            (exact-key only — every hit bit-identical).
        ttl_s: optional max entry age in seconds (None: no TTL; the
            label write clock still evicts on relevant writes).
        capacity: max cached entries; least-recently-used beyond that.
        admit_after: misses a key must accumulate before it is inserted
            (1 = cache on first miss).
        rebuild_every: tail length that triggers a similarity-index
            rebuild per (predicate, k) partition.
        sim_probe: nearest cached queries fetched from the built
            similarity index per probe (cosine is re-checked on each).
        method / setting: fixed method for router-less fill-on-miss.
        telemetry: optional `TelemetrySink` to mirror counters into
            (defaults to the wrapped service's sink, if any).
    """

    def __init__(self, service, *, threshold: float | None = 0.98,
                 ttl_s: float | None = None, capacity: int = 1024,
                 admit_after: int = 1, rebuild_every: int = 32,
                 sim_probe: int = 8, method=None, setting=None,
                 telemetry=None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        if threshold is not None and not (-1.0 <= float(threshold) <= 1.0):
            raise ValueError(
                f"threshold must be in [-1, 1] or None; got {threshold}")
        if admit_after < 1:
            raise ValueError(
                f"admit_after must be >= 1; got {admit_after}")
        self.service = service
        self.threshold = None if threshold is None else float(threshold)
        self.ttl_s = None if ttl_s is None else float(ttl_s)
        self.capacity = int(capacity)
        self.admit_after = int(admit_after)
        self.rebuild_every = int(rebuild_every)
        self.sim_probe = int(sim_probe)
        self._index = getattr(service, "index", service)
        self._sink = (telemetry if telemetry is not None
                      else getattr(service, "telemetry", None))
        if method is None:
            if not callable(getattr(service, "route", None)):
                raise ValueError(
                    "service has no route/execute surface — pass "
                    "method= for router-less serving")
            self._fill = service.search
            # expose the split pipeline only when the inner service has
            # it, so AsyncBatchQueue's feature detection stays truthful
            self.route = service.route
            self.execute = self._execute
        else:
            self._fill = (lambda batch, t=None:
                          service.search(batch, method, setting))
        self._lock = threading.RLock()
        self._entries: OrderedDict[tuple, _Entry] = OrderedDict()
        self._parts: dict[tuple, _SimPart] = {}
        self._seen: dict[tuple, int] = {}        # admission doorkeeper
        self._counters = {
            "hits_exact": 0, "hits_semantic": 0, "hits_transfer": 0,
            "misses": 0, "insertions": 0, "evictions_ttl": 0,
            "evictions_stale": 0, "evictions_capacity": 0}
        # entries/bytes as pull gauges on the process ledger: collected
        # only at scrape/snapshot time, zero cost on the serve path
        self._ledger_key = f"cache:{id(self):x}"
        ledger_mod.get_ledger().register_collector(
            self._ledger_key, self._ledger_gauges)

    def _ledger_gauges(self) -> dict:
        with self._lock:
            return {"entries": len(self._entries),
                    "capacity": self.capacity,
                    "bytes": sum(e.nbytes
                                 for e in self._entries.values())}

    # ---- facade ----------------------------------------------------------
    @property
    def ds(self):
        return getattr(self.service, "ds", None)

    @property
    def index(self):
        return self._index

    @property
    def telemetry(self):
        return self._sink

    @property
    def tracer(self):
        """The wrapped service's tracer (the queue discovers it here)."""
        return getattr(self.service, "tracer", None)

    @property
    def slo(self):
        """The wrapped service's SLO engine (hit-path observations)."""
        return getattr(self.service, "slo", None)

    @property
    def obslog(self):
        """The wrapped service's wide-event log (hit-path events)."""
        return getattr(self.service, "obslog", None)

    def close(self) -> None:
        """Drop every entry and the built similarity indexes. The
        wrapped service is not closed — the cache doesn't own it."""
        ledger_mod.get_ledger().deregister_collector(self._ledger_key)
        with self._lock:
            self._entries.clear()
            self._seen.clear()
            for part in self._parts.values():
                part.close()
            self._parts.clear()

    clear = close

    def __enter__(self) -> "SemanticResultCache":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict:
        with self._lock:
            c = dict(self._counters)
            c["entries"] = len(self._entries)
            c["capacity"] = self.capacity
            c["partitions"] = len(self._parts)
        hits = c["hits_exact"] + c["hits_semantic"] + c["hits_transfer"]
        seen = hits + c["misses"]
        c["hit_rate"] = round(hits / seen, 4) if seen else None
        return c

    def _note(self, counter: str, evicted: _Entry | None = None) -> None:
        """Bump a counter (cache lock held) and mirror it to the sink."""
        self._counters[counter] += 1
        if evicted is not None:
            evicted.alive = False
            self._entries.pop(evicted.ekey, None)
        if self._sink is not None:
            self._sink.note(f"cache_{counter}")

    # ---- probe (the hit path: no routing, no search) ---------------------
    @staticmethod
    def _ekey(vector: np.ndarray, bitmap: np.ndarray, pred, k) -> tuple:
        return (int(pred), int(k), vector.tobytes(), bitmap.tobytes())

    def _clock(self, labels=None) -> int:
        lc = getattr(self._index, "label_clock", None)
        return int(lc(labels)) if callable(lc) else 0

    def _fresh(self, entry: _Entry, now: float) -> bool:
        """TTL + label-write-clock staleness check; evicts on failure
        (cache lock held)."""
        if not entry.alive:
            return False
        if entry.expires_at is not None and now >= entry.expires_at:
            self._note("evictions_ttl", entry)
            return False
        if self._clock(entry.labels) > entry.clock:
            self._note("evictions_stale", entry)
            return False
        return True

    def _current_rows(self, entry: _Entry) -> tuple:
        """(ids, distances, keys) in the current generation's id space.
        Same generation: the cached arrays verbatim (bit-identical to
        the search that filled them). After a compaction: ids re-resolve
        through the stable keys and rows re-sort by (distance, id) —
        compaction never changes the live row set, so a fresh entry's
        keys are all still live."""
        gen = int(getattr(self._index, "generation", 0))
        if entry.generation != gen:
            ids = np.full_like(entry.ids, -1)
            valid = entry.keys >= 0
            if valid.any():
                rows = self._index.rows_of(entry.keys[valid])
                ids[valid] = rows.astype(np.int32)
            dist_key = np.where(ids >= 0, entry.distances, np.inf)
            order = np.lexsort((ids, dist_key))
            entry.ids = ids[order]
            entry.distances = entry.distances[order]
            entry.keys = entry.keys[order]
            entry.generation = gen
        return (entry.ids.copy(), entry.distances.copy(),
                entry.keys.copy())

    def _rescore(self, vector: np.ndarray, ids: np.ndarray,
                 keys: np.ndarray) -> tuple:
        """Exact squared-L2 of the given rows against `vector`,
        re-sorted ascending — the semantic-hit serving path."""
        fetch = getattr(self._index, "fetch", None)
        if callable(fetch):
            vecs = np.asarray(fetch(ids), dtype=np.float32)
        else:
            vecs = np.full((ids.size, vector.size), np.nan, np.float32)
            valid = ids >= 0
            if valid.any():
                vecs[valid] = self._index.ds.vectors[ids[valid]]
        diff = vecs.astype(np.float64) - vector.astype(np.float64)
        d = (diff ** 2).sum(axis=1).astype(np.float32)
        dist_key = np.where(ids >= 0, d, np.inf)
        order = np.lexsort((ids, dist_key))
        d = np.where(ids >= 0, d, np.float32(np.nan)).astype(np.float32)
        return ids[order], d[order], keys[order]

    def _probe_query(self, vector: np.ndarray, bitmap: np.ndarray,
                     pred, k: int):
        """One query against the cache: (ids, distances, keys, kind)
        or None on miss. Never routes, never searches the corpus."""
        vector = np.ascontiguousarray(vector, dtype=np.float32)
        bitmap = np.ascontiguousarray(bitmap, dtype=np.uint32)
        ekey = self._ekey(vector, bitmap, pred, k)
        now = time.monotonic()
        with self._lock:
            entry = self._entries.get(ekey)
            if entry is not None:
                if self._fresh(entry, now):
                    self._entries.move_to_end(ekey)
                    self._note("hits_exact")
                    return (*self._current_rows(entry), "exact")
            if self.threshold is not None:
                hit = self._probe_semantic(vector, bitmap, pred, k, now)
                if hit is not None:
                    return hit
            self._note("misses")
            return None

    def _probe_semantic(self, vector, bitmap, pred, k, now):
        part = self._parts.get((int(pred), int(k)))
        if part is None:
            return None
        vnorm = float(np.sqrt((vector.astype(np.float64) ** 2).sum()))
        if vnorm == 0.0:
            return None
        best, best_cos = None, float(self.threshold)
        for cand in part.candidates(vector, bitmap, self.sim_probe):
            if not cand.alive or cand.vnorm == 0.0:
                continue
            cos = float(vector.astype(np.float64)
                        @ cand.vector.astype(np.float64)) \
                / (vnorm * cand.vnorm)
            if cos >= best_cos:
                best, best_cos = cand, cos
        if best is not None and self._fresh(best, now):
            self._entries.move_to_end(best.ekey)
            self._note("hits_semantic")
            ids, _, keys = self._current_rows(best)
            return (*self._rescore(vector, ids, keys), "semantic")
        if Predicate(pred) in (Predicate.AND, Predicate.OR):
            return self._probe_transfer(part, vector, vnorm, bitmap,
                                        Predicate(pred), k, now)
        return None

    def _row_bitmaps(self, ids: np.ndarray) -> np.ndarray | None:
        """[R, W] packed bitmaps of current-generation row ids, or None
        when they can't be resolved (conservative: no transfer)."""
        bm_of = getattr(self._index, "_bitmaps_of", None)
        if callable(bm_of):
            try:
                return np.asarray(bm_of(np.asarray(ids, np.int64)),
                                  dtype=np.uint32)
            except Exception:
                return None
        ds = getattr(self._index, "ds", None)
        if ds is None:
            return None
        ids = np.asarray(ids)
        if ids.size and int(ids.max()) >= ds.n:
            return None   # rows beyond the sealed dataset (sharded delta)
        return np.asarray(ds.bitmaps[ids], dtype=np.uint32)

    def _probe_transfer(self, part, vector, vnorm, bitmap,
                        pred: Predicate, k, now):
        """Subset/superset bitmap transfer: serve from a cached entry
        whose filter is provably *looser* than the query's — OR with
        cached labels ⊇ query labels, AND with cached labels ⊆ query
        labels — when every valid cached row also passes the tighter
        query filter.  The query's admissible rows are then a subset of
        the cached search's, and a top-k that lies entirely inside the
        subset is that subset's top-k too, so the transfer is exact for
        the served row set.  Any valid row failing the re-check means
        rows outside the query's filter may have crowded out admissible
        ones — that's a miss, never a guess."""
        qb = bitmap
        qkey = bitmap.tobytes()
        best, best_cos = None, float(self.threshold)
        for cand in part.entries():
            if cand.vnorm == 0.0 or cand.bitmap.tobytes() == qkey:
                continue
            cb = cand.bitmap
            if pred == Predicate.OR:
                looser = bool(((cb & qb) == qb).all())   # qb ⊆ cb
            else:                                        # AND
                # a label-less cached filter is invisible to the write
                # clock — new rows matching the query would go unseen
                looser = (cand.labels.size > 0
                          and bool(((cb & qb) == cb).all()))  # cb ⊆ qb
            if not looser:
                continue
            cos = float(vector.astype(np.float64)
                        @ cand.vector.astype(np.float64)) \
                / (vnorm * cand.vnorm)
            if cos >= best_cos:
                best, best_cos = cand, cos
        if best is None or not self._fresh(best, now):
            return None
        ids, _, keys = self._current_rows(best)
        valid = ids >= 0
        if valid.any():
            rbms = self._row_bitmaps(ids[valid])
            if rbms is None:
                return None
            if pred == Predicate.OR:
                ok = ((rbms & qb) != 0).any(axis=1)
            else:
                ok = ((rbms & qb) == qb).all(axis=1)
            if not bool(ok.all()):
                return None
        self._entries.move_to_end(best.ekey)
        self._note("hits_transfer")
        return (*self._rescore(vector, ids, keys), "transfer")

    def probe_one(self, vector, bitmap, pred, k: int = 10):
        """Single-query probe for `AsyncBatchQueue.submit`: a
        `repro.ann.service.QueryResult` on hit, None on miss. The hit
        path bypasses routing and search entirely."""
        from repro.ann.service import QueryResult

        t0 = time.monotonic()
        hit = self._probe_query(np.asarray(vector, dtype=np.float32),
                                np.asarray(bitmap, dtype=np.uint32),
                                Predicate(pred), int(k))
        if hit is None:
            return None
        ids, dists, keys, kind = hit
        lat_us = (time.monotonic() - t0) * 1e6
        tracer = self.tracer
        tid = None
        if tracer is not None:
            # hits never reach the batch pipeline, so they get their own
            # (tiny, retroactive) trace — cache provenance + latency
            root = tracer.start("cache_probe", pred=int(pred), k=int(k),
                                cache=kind)
            root.t0 = t0
            tracer.finish(root)
            tid = root.trace_id
        slo = self.slo
        if slo is not None:
            slo.observe_request(lat_us, pred=int(pred))
        olog = self.obslog
        if olog is not None:
            olog.emit({"ts": round(time.time(), 6), "trace": tid,
                       "pred": int(pred), "k": int(k), "batch_q": 1,
                       "qi": 0, "lat_us": round(lat_us, 1),
                       "cache": kind,
                       "slo": slo.state() if slo is not None else None})
        return QueryResult(ids=ids, distances=dists, decision=None,
                           keys=keys, cache=kind)

    # ---- serve (probe + per-group fill-on-miss) --------------------------
    def search(self, batch: QueryBatch, *, t: float | None = None
               ) -> SearchResult:
        """Probe every query; the misses — and only the misses — flow
        through the wrapped service as one sub-batch, and their results
        are admitted. `res.cache[i]` says how query i was served."""
        with trace.maybe_trace(self.tracer, "cache_search", q=batch.q):
            t0 = time.perf_counter()
            with trace.span("cache.probe", q=batch.q):
                hits = [self._probe_query(batch.vectors[i],
                                          batch.bitmaps[i],
                                          batch.pred, batch.k)
                        for i in range(batch.q)]
                miss = [i for i, h in enumerate(hits) if h is None]
                trace.annotate(misses=len(miss))
            ids = np.full((batch.q, batch.k), -1, np.int32)
            dists = np.full((batch.q, batch.k), np.nan, np.float32)
            keys = np.full((batch.q, batch.k), -1, np.int64)
            tags: list = [None] * batch.q
            decisions = None
            timings: dict = {}
            for i, h in enumerate(hits):
                if h is not None:
                    ids[i], dists[i], keys[i], tags[i] = h
            t1 = time.perf_counter()
            if miss:
                sub = batch.take(np.asarray(miss))
                clock0, gen0 = self._stamp()
                res = self._fill(sub, t=t)
                with trace.span("cache.admit", q=sub.q):
                    self._admit(sub, res, clock0, gen0)
                midx = np.asarray(miss)
                ids[midx] = res.ids
                dists[midx] = res.distances
                if res.keys is not None:
                    keys[midx] = res.keys
                if res.decisions is not None:
                    decisions = [None] * batch.q
                    for j, i in enumerate(miss):
                        decisions[i] = res.decisions[j]
                timings.update(res.timings)
            total = time.perf_counter() - t0
            timings["cache_s"] = timings.get("cache_s", 0.0) + (t1 - t0)
            timings["total_s"] = total
            kinds: dict[str, int] = {}
            for tag in tags:
                kinds[tag or "miss"] = kinds.get(tag or "miss", 0) + 1
            trace.annotate(cache=kinds)
            return SearchResult(ids=ids, distances=dists,
                                decisions=decisions, timings=timings,
                                keys=keys, cache=tags)

    def _execute(self, batch: QueryBatch, decisions) -> SearchResult:
        """`execute` facade for the pipelined queue: run the inner
        execute, admit the results. Probing already happened in
        `submit`, so everything reaching here is a miss."""
        clock0, gen0 = self._stamp()
        res = self.service.execute(batch, decisions)
        with trace.span("cache.admit", q=batch.q):
            self._admit(batch, res, clock0, gen0)
        return res

    # ---- admission -------------------------------------------------------
    def _stamp(self) -> tuple:
        """(write clock, generation) read *before* the backing search:
        a write or compaction racing the fill then makes the entry
        conservatively stale/remapped rather than silently fresh."""
        return (self._clock(None),
                int(getattr(self._index, "generation", 0)))

    def _admit(self, batch: QueryBatch, res: SearchResult,
               clock: int, generation: int) -> None:
        expires = (None if self.ttl_s is None
                   else time.monotonic() + self.ttl_s)
        keys = (res.keys if res.keys is not None
                else res.ids.astype(np.int64))
        with self._lock:
            for i in range(batch.q):
                vec = batch.vectors[i]
                bm = batch.bitmaps[i]
                ekey = self._ekey(np.ascontiguousarray(vec),
                                  np.ascontiguousarray(bm),
                                  batch.pred, batch.k)
                if self.admit_after > 1:
                    n = self._seen.get(ekey, 0) + 1
                    if n < self.admit_after:
                        # doorkeeper: bounded — reset rather than grow
                        if len(self._seen) > max(4 * self.capacity, 1024):
                            self._seen.clear()
                        self._seen[ekey] = n
                        continue
                    self._seen.pop(ekey, None)
                old = self._entries.pop(ekey, None)
                if old is not None:
                    old.alive = False
                entry = _Entry(vec, bm, batch.pred, batch.k,
                               clock=clock, generation=generation,
                               ids=res.ids[i], distances=res.distances[i],
                               keys=keys[i], expires_at=expires,
                               ekey=ekey)
                self._entries[ekey] = entry
                self._counters["insertions"] += 1
                if self._sink is not None:
                    self._sink.note("cache_insertions")
                pk = (int(batch.pred), int(batch.k))
                part = self._parts.get(pk)
                if part is None:
                    universe = getattr(self._index, "_universe", None)
                    if universe is None:
                        universe = self._index.ds.universe
                    part = _SimPart(int(universe),
                                    f"cacheq/{pk[0]}/{pk[1]}")
                    self._parts[pk] = part
                part.add(entry, self.rebuild_every)
                while len(self._entries) > self.capacity:
                    _, lru = self._entries.popitem(last=False)
                    lru.alive = False
                    self._counters["evictions_capacity"] += 1
                    if self._sink is not None:
                        self._sink.note("cache_evictions_capacity")
