"""Prometheus text exposition + a lightweight scrape endpoint.

`metrics_text()` renders one consistent snapshot of the serving stack's
observability surfaces in Prometheus text format 0.0.4:

* `TelemetrySink` — query/batch counters, per-(method, ps, predicate)
  cells, per-shard stage-time cells (skew), named counters, and the
  ring-derived latency percentiles as gauges;
* `Tracer` — per-span latency histograms with *fixed* log2-µs buckets
  (`trace.BUCKET_BOUNDS_US` — bucket layout is independent of any ring
  capacity, so rates and quantiles are comparable across deployments
  and restarts) plus trace/keep/drop counters;
* `SemanticResultCache` — hit/miss/eviction counters and occupancy;
* `AsyncBatchQueue` — served queries/batches, submit-time cache hits,
  queue-depth high-water mark, flush reasons;
* `OnlineBenchmarkTable` — table version, audited-vs-offline drift,
  and the shard-keyed EWMA QPS cells (shard-divergent throughput is
  visible per shard, not just in aggregate);
* `ResourceLedger` — held leases per kind/owner (counts + bytes), leak
  count, lifetime acquire/release counters, and every registered
  collector gauge (delta/device bytes, cache occupancy, WAL backlog,
  queue depth);
* `SLOEngine` — per-objective burn rates per alert window, firing
  state, and the alert count (**each scrape runs one evaluation
  pass**, so scraping *is* the alerting cadence when no background
  evaluator is started);
* `WideEventLog` — emitted/written/dropped/rotation counters and the
  active file size.

`MetricsServer` serves `/metrics` (the exposition) and `/healthz`
(JSON readiness: HTTP 200 while ``status == "ok"``, 503 once the
health payload degrades — see `backpressure_health`) on a daemon
`ThreadingHTTPServer`, plus the debug surfaces `/statusz` (one merged
operator view), `/debug/ledger` and `/debug/slo`.  `rag_serve.py
--metrics-port` wires it up.
"""

from __future__ import annotations

import json
import math
import threading
import time
from typing import Callable

__all__ = ["metrics_text", "MetricsServer", "backpressure_health"]

_PREFIX = "ann"


def _esc(v) -> str:
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _esc_help(v) -> str:
    # HELP text escapes only backslash and newline (exposition format
    # 0.0.4) — quotes stay literal, unlike label values
    return str(v).replace("\\", "\\\\").replace("\n", "\\n")


def _fmt(value) -> str:
    if value is None:
        return "0"
    if isinstance(value, float):
        if math.isinf(value):
            return "+Inf" if value > 0 else "-Inf"
        if math.isnan(value):
            return "NaN"
    return repr(float(value)) if isinstance(value, float) else str(value)


class _Writer:
    def __init__(self):
        self.lines: list[str] = []
        self._typed: set[str] = set()

    def header(self, name: str, mtype: str, help_: str) -> None:
        if name in self._typed:
            return
        self._typed.add(name)
        self.lines.append(f"# HELP {name} {_esc_help(help_)}")
        self.lines.append(f"# TYPE {name} {mtype}")

    def sample(self, name: str, labels: dict | None, value) -> None:
        if labels:
            lab = ",".join(f'{k}="{_esc(v)}"' for k, v in labels.items())
            self.lines.append(f"{name}{{{lab}}} {_fmt(value)}")
        else:
            self.lines.append(f"{name} {_fmt(value)}")

    def text(self) -> str:
        return "\n".join(self.lines) + "\n"


def _sink_metrics(w: _Writer, sink, prefix: str) -> None:
    from repro.ann.predicates import Predicate

    s = sink.stats()
    w.header(f"{prefix}_queries_total", "counter",
             "Queries recorded by the telemetry sink.")
    w.sample(f"{prefix}_queries_total", None, s["queries"])
    w.header(f"{prefix}_batches_total", "counter",
             "Executed batches recorded by the telemetry sink.")
    w.sample(f"{prefix}_batches_total", None, s["batches"])
    w.header(f"{prefix}_latency_us", "gauge",
             "Ring-derived per-query latency percentiles (µs).")
    for q, v in s["latency_us"].items():
        w.sample(f"{prefix}_latency_us", {"quantile": q}, v)
    w.header(f"{prefix}_method_queries_total", "counter",
             "Queries served per routed method.")
    for m, n in sorted(s["by_method"].items()):
        w.sample(f"{prefix}_method_queries_total", {"method": m}, n)
    w.header(f"{prefix}_cell_queries_total", "counter",
             "Queries per (method, param-setting, predicate) cell.")
    w.header(f"{prefix}_cell_latency_us_mean", "gauge",
             "Mean per-query latency per cell (µs).")
    for (m, ps, p), (n, us) in sorted(sink.cell_aggregates().items(),
                                      key=lambda kv: str(kv[0])):
        if n <= 0:
            continue
        lab = {"method": m, "ps": ps if ps is not None else "",
               "pred": Predicate(p).name}
        w.sample(f"{prefix}_cell_queries_total", lab, n)
        w.sample(f"{prefix}_cell_latency_us_mean", lab, us / n)
    w.header(f"{prefix}_shard_stage_seconds_total", "counter",
             "Per-shard stage seconds (fan-out skew).")
    w.header(f"{prefix}_shard_stage_calls_total", "counter",
             "Per-shard stage fold count.")
    for (sh, stage), (n, sec) in sorted(sink.shard_aggregates().items()):
        lab = {"shard": sh, "stage": stage}
        w.sample(f"{prefix}_shard_stage_seconds_total", lab, sec)
        w.sample(f"{prefix}_shard_stage_calls_total", lab, n)
    w.header(f"{prefix}_counter", "counter",
             "Named sink counters (stage seconds, cache notes, waits).")
    for name, val in sorted(sink.counter_values().items()):
        w.sample(f"{prefix}_counter", {"name": name}, val)


def _tracer_metrics(w: _Writer, tracer, prefix: str) -> None:
    from repro.ann.trace import BUCKET_BOUNDS_US

    t = tracer.stats()
    w.header(f"{prefix}_traces_total", "counter",
             "Finished traces, by sampling outcome.")
    for key in ("traces", "kept", "dropped", "slow", "errors"):
        w.sample(f"{prefix}_traces_total", {"outcome": key}, t[key])
    w.header(f"{prefix}_flight_size", "gauge",
             "Span trees currently held by the flight recorder.")
    w.sample(f"{prefix}_flight_size", None, t["flight_size"])
    name = f"{prefix}_span_latency_us"
    w.header(name, "histogram",
             "Per-span latency, fixed log2-µs buckets "
             "(independent of ring capacity).")
    for span_name, h in sorted(tracer.histograms().items()):
        acc = 0
        for bound, c in zip(BUCKET_BOUNDS_US, h["counts"]):
            acc += c
            le = "+Inf" if math.isinf(bound) else _fmt(bound)
            w.sample(f"{name}_bucket", {"span": span_name, "le": le}, acc)
        w.sample(f"{name}_sum", {"span": span_name}, h["sum_us"])
        w.sample(f"{name}_count", {"span": span_name}, h["count"])


def _cache_metrics(w: _Writer, cache, prefix: str) -> None:
    c = cache.stats()
    w.header(f"{prefix}_cache_events_total", "counter",
             "Semantic-cache events (hits by kind, misses, evictions).")
    for key, val in sorted(c.items()):
        if key in ("entries", "capacity", "partitions", "hit_rate"):
            continue
        w.sample(f"{prefix}_cache_events_total", {"event": key}, val)
    w.header(f"{prefix}_cache_entries", "gauge", "Cached entries.")
    w.sample(f"{prefix}_cache_entries", None, c["entries"])
    w.header(f"{prefix}_cache_capacity", "gauge", "Cache capacity.")
    w.sample(f"{prefix}_cache_capacity", None, c["capacity"])
    w.header(f"{prefix}_cache_hit_rate", "gauge",
             "Lifetime hit rate (0 when nothing probed yet).")
    w.sample(f"{prefix}_cache_hit_rate", None, c["hit_rate"] or 0.0)


def _queue_metrics(w: _Writer, queue, prefix: str) -> None:
    s = queue.stats()
    w.header(f"{prefix}_queue_queries_total", "counter",
             "Queries served through the async batch queue.")
    w.sample(f"{prefix}_queue_queries_total", None, s["queries"])
    w.header(f"{prefix}_queue_batches_total", "counter",
             "Micro-batches flushed by the queue worker.")
    w.sample(f"{prefix}_queue_batches_total", None, s["batches"])
    w.header(f"{prefix}_queue_cache_hits_total", "counter",
             "Queries answered from the cache at submit time.")
    w.sample(f"{prefix}_queue_cache_hits_total", None, s["cache_hits"])
    w.header(f"{prefix}_queue_pending", "gauge",
             "Requests currently waiting for a flush.")
    w.sample(f"{prefix}_queue_pending", None, s["pending"])
    w.header(f"{prefix}_queue_depth_high_water", "gauge",
             "Queue-depth high-water mark.")
    w.sample(f"{prefix}_queue_depth_high_water", None,
             s["max_queue_depth"])
    w.header(f"{prefix}_queue_flushes_total", "counter",
             "Flushes by trigger reason.")
    for reason, n in sorted(s["flush_reasons"].items()):
        w.sample(f"{prefix}_queue_flushes_total", {"reason": reason}, n)


def _ledger_metrics(w: _Writer, ledger, prefix: str) -> None:
    snap = ledger.snapshot()
    w.header(f"{prefix}_ledger_leases_held", "gauge",
             "Held resource leases per (kind, owner).")
    w.header(f"{prefix}_ledger_lease_count", "gauge",
             "Summed lease counts per (kind, owner).")
    w.header(f"{prefix}_ledger_lease_bytes", "gauge",
             "Summed lease bytes per (kind, owner).")
    for kind, owners in sorted(snap["held"].items()):
        for owner, agg in sorted(owners.items()):
            lab = {"kind": kind, "owner": owner}
            w.sample(f"{prefix}_ledger_leases_held", lab, agg["leases"])
            w.sample(f"{prefix}_ledger_lease_count", lab, agg["count"])
            w.sample(f"{prefix}_ledger_lease_bytes", lab, agg["bytes"])
    w.header(f"{prefix}_ledger_acquired_total", "counter",
             "Lifetime lease acquisitions per kind.")
    w.header(f"{prefix}_ledger_released_total", "counter",
             "Lifetime lease releases per kind.")
    for kind, c in sorted(snap["counters"].items()):
        w.sample(f"{prefix}_ledger_acquired_total", {"kind": kind},
                 c["acquired"])
        w.sample(f"{prefix}_ledger_released_total", {"kind": kind},
                 c["released"])
    w.header(f"{prefix}_ledger_leaks", "gauge",
             "Leases held past the configured leak age.")
    w.sample(f"{prefix}_ledger_leaks", None, len(snap["leaks"]))
    w.header(f"{prefix}_ledger_gauge", "gauge",
             "Collector-sourced resource gauges "
             "(delta/device bytes, WAL backlog, queue depth, cache).")
    for source, gauges in sorted(snap["gauges"].items()):
        for gname, val in sorted(gauges.items()):
            if gname.startswith("_"):
                continue
            w.sample(f"{prefix}_ledger_gauge",
                     {"source": source, "name": gname}, val)
    w.header(f"{prefix}_ledger_collector_errors", "gauge",
             "Registered collectors that raised at scrape time.")
    w.sample(f"{prefix}_ledger_collector_errors", None,
             len(snap.get("collector_errors", {})))


def _table_metrics(w: _Writer, table, prefix: str) -> None:
    w.header(f"{prefix}_table_version", "counter",
             "Online benchmark-table version (bumps per observation).")
    w.sample(f"{prefix}_table_version", None, table.version)
    w.header(f"{prefix}_table_shard_qps", "gauge",
             "Shard-keyed EWMA QPS cells folded from per-shard "
             "telemetry (shard-divergent throughput, per shard).")
    w.header(f"{prefix}_table_shard_samples_total", "counter",
             "Samples folded into each shard cell.")
    for (ds, shard, stage), cell in sorted(table.shard_cells().items()):
        lab = {"ds": ds, "shard": shard, "stage": stage}
        w.sample(f"{prefix}_table_shard_qps", lab, cell["qps"])
        w.sample(f"{prefix}_table_shard_samples_total", lab, cell["n"])
    w.header(f"{prefix}_table_shard_divergence", "gauge",
             "max/min shard EWMA QPS ratio (1 = even, 0 = <2 shards).")
    w.sample(f"{prefix}_table_shard_divergence", None,
             table.shard_divergence())
    w.header(f"{prefix}_table_max_drift", "gauge",
             "Largest audited-vs-offline recall divergence.")
    w.sample(f"{prefix}_table_max_drift", None, table.max_drift())


def _slo_metrics(w: _Writer, slo, prefix: str) -> None:
    # evaluate() is deliberately called at scrape time: with no
    # background evaluator running, the scrape cadence is the alerting
    # cadence (rising-edge alerts are recorded on the engine)
    status = slo.evaluate()
    st = slo.stats()
    w.header(f"{prefix}_slo_firing", "gauge",
             "1 when the objective's burn-rate alert is firing.")
    w.header(f"{prefix}_slo_burn_rate", "gauge",
             "Error-budget burn rate per (objective, window, span).")
    w.header(f"{prefix}_slo_events_total", "counter",
             "Events observed per objective.")
    for name, obj in sorted(status.items()):
        w.sample(f"{prefix}_slo_firing", {"objective": name},
                 1 if obj["firing"] else 0)
        for win in obj["windows"]:
            wl = _fmt(float(win["long_s"]))
            w.sample(f"{prefix}_slo_burn_rate",
                     {"objective": name, "window_s": wl, "span": "long"},
                     win["burn_long"])
            w.sample(f"{prefix}_slo_burn_rate",
                     {"objective": name, "window_s": wl, "span": "short"},
                     win["burn_short"])
        w.sample(f"{prefix}_slo_events_total", {"objective": name},
                 obj["observed"])
    w.header(f"{prefix}_slo_alerts_total", "counter",
             "Rising-edge burn-rate alerts since start.")
    w.sample(f"{prefix}_slo_alerts_total", None, st["alerts"])


def _obslog_metrics(w: _Writer, obslog, prefix: str) -> None:
    s = obslog.stats()
    w.header(f"{prefix}_obslog_events_total", "counter",
             "Wide events by disposition (emitted/written/dropped).")
    for key in ("emitted", "written", "dropped"):
        w.sample(f"{prefix}_obslog_events_total", {"disposition": key},
                 s[key])
    w.header(f"{prefix}_obslog_rotations_total", "counter",
             "Log-file rotations performed by the writer.")
    w.sample(f"{prefix}_obslog_rotations_total", None, s["rotations"])
    w.header(f"{prefix}_obslog_write_errors_total", "counter",
             "Writer I/O errors (events are shed, never block).")
    w.sample(f"{prefix}_obslog_write_errors_total", None,
             s["write_errors"])
    w.header(f"{prefix}_obslog_file_bytes", "gauge",
             "Size of the active wide-event log file.")
    w.sample(f"{prefix}_obslog_file_bytes", None, s["file_bytes"])


def metrics_text(*, sink=None, tracer=None, cache=None, queue=None,
                 ledger=None, slo=None, obslog=None, table=None,
                 service=None, prefix: str = _PREFIX) -> str:
    """Render one Prometheus text-format snapshot of whatever surfaces
    are passed.  `service=` is a convenience: its `telemetry`,
    `tracer`, `slo` and `obslog` attributes fill the matching slots
    when those are omitted (an `OnlineBenchmarkTable` behind the
    service's router fills `table`, and a `SemanticResultCache` passed
    as `service` fills `cache`)."""
    if service is not None:
        if sink is None:
            sink = getattr(service, "telemetry", None)
        if tracer is None:
            tracer = getattr(service, "tracer", None)
        if slo is None:
            slo = getattr(service, "slo", None)
        if obslog is None:
            obslog = getattr(service, "obslog", None)
        if table is None:
            t = getattr(getattr(service, "router", None), "table", None)
            if hasattr(t, "shard_cells"):
                table = t
        if cache is None and hasattr(service, "probe_one"):
            cache = service
    w = _Writer()
    if sink is not None:
        _sink_metrics(w, sink, prefix)
    if tracer is not None:
        _tracer_metrics(w, tracer, prefix)
    if cache is not None:
        _cache_metrics(w, cache, prefix)
    if queue is not None:
        _queue_metrics(w, queue, prefix)
    if table is not None:
        _table_metrics(w, table, prefix)
    if ledger is not None:
        _ledger_metrics(w, ledger, prefix)
    if slo is not None:
        _slo_metrics(w, slo, prefix)
    if obslog is not None:
        _obslog_metrics(w, obslog, prefix)
    if not w.lines:
        w.header(f"{prefix}_up", "gauge", "Exporter liveness.")
        w.sample(f"{prefix}_up", None, 1)
    return w.text()


def backpressure_health(*, queue=None, wal=None,
                        queue_high_water: int = 256,
                        wal_records_max: int = 4096,
                        wal_bytes_max: int = 64 << 20,
                        extra: Callable[[], dict] | None = None,
                        ) -> Callable[[], dict]:
    """Build a `/healthz` payload callable that degrades on
    backpressure, not just on exceptions.

    The returned callable reports ``status: "degraded"`` (which
    `MetricsServer` maps to HTTP 503) when the async batch queue's
    pending depth exceeds `queue_high_water` or the WAL's fsync
    backlog exceeds `wal_records_max` records / `wal_bytes_max`
    bytes.  `extra()` results are merged in; an ``extra`` that sets
    ``status`` itself wins only if it degrades further.
    """
    def health() -> dict:
        payload: dict = {"status": "ok"}
        reasons: list[str] = []
        if queue is not None:
            pending = int(queue.stats()["pending"])
            payload["queue_pending"] = pending
            if pending > queue_high_water:
                reasons.append(
                    f"queue_pending {pending} > {queue_high_water}")
        if wal is not None:
            bl = wal.backlog()
            payload["wal_backlog_records"] = int(bl["records"])
            payload["wal_backlog_bytes"] = int(bl["bytes"])
            if bl["records"] > wal_records_max:
                reasons.append(
                    f"wal_backlog_records {bl['records']} > "
                    f"{wal_records_max}")
            if bl["bytes"] > wal_bytes_max:
                reasons.append(
                    f"wal_backlog_bytes {bl['bytes']} > {wal_bytes_max}")
        if extra is not None:
            ext = dict(extra())
            ext_status = ext.pop("status", "ok")
            payload.update(ext)
            if ext_status != "ok":
                reasons.append(f"extra: {ext_status}")
        if reasons:
            payload["status"] = "degraded"
            payload["reasons"] = reasons
        return payload

    return health


class MetricsServer:
    """Daemon HTTP server exposing `/metrics` (Prometheus text),
    `/healthz` (JSON readiness), `/statusz` (merged operator view) and
    the `/debug/ledger` / `/debug/slo` JSON surfaces.

    Args:
        render: zero-arg callable returning the exposition text —
            typically `lambda: metrics_text(sink=..., tracer=...)`.
        host / port: bind address; port 0 picks a free port (read it
            back from `.port`).
        health: optional zero-arg callable returning a JSON-serialisable
            health payload (merged over {"status": "ok"}).  A payload
            whose ``status`` is anything but ``"ok"`` — including one
            produced by `backpressure_health` on queue/WAL backlog —
            is served with HTTP 503 so load-balancer probes actually
            drain the replica, instead of the former always-200.
        ledger / slo / obslog: optional observability handles backing
            `/debug/ledger`, `/debug/slo` and the `/statusz` summary.
        statusz: optional zero-arg callable merged into `/statusz`.
    """

    def __init__(self, render: Callable[[], str], *,
                 host: str = "127.0.0.1", port: int = 0,
                 health: Callable[[], dict] | None = None,
                 ledger=None, slo=None, obslog=None,
                 statusz: Callable[[], dict] | None = None):
        from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

        outer = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802  (http.server API)
                route = self.path.split("?", 1)[0]
                if route == "/metrics":
                    try:
                        body = outer.render().encode()
                    except Exception as e:   # surface, don't kill serving
                        self._reply(500, f"# render error: {e}\n".encode(),
                                    "text/plain; charset=utf-8")
                        return
                    self._reply(200, body,
                                "text/plain; version=0.0.4; charset=utf-8")
                elif route == "/healthz":
                    payload = {"status": "ok"}
                    if outer.health is not None:
                        try:
                            payload.update(outer.health())
                        except Exception as e:
                            payload = {"status": "degraded",
                                       "error": str(e)}
                    code = 200 if payload.get("status") == "ok" else 503
                    self._json(code, payload)
                elif route == "/statusz":
                    self._json(200, outer._statusz())
                elif route == "/debug/ledger":
                    if outer.ledger is None:
                        self._json(404, {"error": "no ledger attached"})
                    else:
                        self._debug_json(lambda: outer.ledger.snapshot())
                elif route == "/debug/slo":
                    if outer.slo is None:
                        self._json(404, {"error": "no slo engine attached"})
                    else:
                        self._debug_json(lambda: outer.slo.status())
                else:
                    self._reply(404, b"not found\n",
                                "text/plain; charset=utf-8")

            def _debug_json(self, fn) -> None:
                try:
                    self._json(200, fn())
                except Exception as e:
                    self._json(500, {"error": str(e)})

            def _json(self, code: int, payload) -> None:
                body = (json.dumps(payload, default=str) + "\n").encode()
                self._reply(code, body, "application/json")

            def _reply(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args) -> None:   # silence access log
                pass

        self.render = render
        self.health = health
        self.ledger = ledger
        self.slo = slo
        self.obslog = obslog
        self.statusz = statusz
        self._srv = ThreadingHTTPServer((host, int(port)), _Handler)
        self._srv.daemon_threads = True
        self.host, self.port = self._srv.server_address[:2]
        self._thread = threading.Thread(
            target=self._srv.serve_forever, name="ann-metrics",
            daemon=True)
        self._thread.start()

    def _statusz(self) -> dict:
        """One compact operator view: health, SLO state, resource
        accounting and wide-event-log throughput, each section guarded
        so a failing surface degrades to an error string."""
        out: dict = {"t_wall": time.time()}
        try:
            payload = {"status": "ok"}
            if self.health is not None:
                payload.update(self.health())
            out["health"] = payload
        except Exception as e:
            out["health"] = {"status": "degraded", "error": str(e)}
        if self.slo is not None:
            try:
                self.slo.evaluate()
                out["slo"] = {"state": self.slo.state(),
                              **self.slo.stats()}
            except Exception as e:
                out["slo"] = {"error": str(e)}
        if self.ledger is not None:
            try:
                snap = self.ledger.snapshot()
                out["ledger"] = {"held": snap["held"],
                                 "leaks": len(snap["leaks"]),
                                 "collector_errors":
                                     snap.get("collector_errors", {})}
            except Exception as e:
                out["ledger"] = {"error": str(e)}
        if self.obslog is not None:
            try:
                out["obslog"] = self.obslog.stats()
            except Exception as e:
                out["obslog"] = {"error": str(e)}
        if self.statusz is not None:
            try:
                out.update(self.statusz())
            except Exception as e:
                out["statusz_error"] = str(e)
        return out

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        self._srv.shutdown()
        self._srv.server_close()
        self._thread.join(timeout=10)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
