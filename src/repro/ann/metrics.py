"""Prometheus text exposition + a lightweight scrape endpoint.

`metrics_text()` renders one consistent snapshot of the serving stack's
observability surfaces in Prometheus text format 0.0.4:

* `TelemetrySink` — query/batch counters, per-(method, ps, predicate)
  cells, per-shard stage-time cells (skew), named counters, and the
  ring-derived latency percentiles as gauges;
* `Tracer` — per-span latency histograms with *fixed* log2-µs buckets
  (`trace.BUCKET_BOUNDS_US` — bucket layout is independent of any ring
  capacity, so rates and quantiles are comparable across deployments
  and restarts) plus trace/keep/drop counters;
* `SemanticResultCache` — hit/miss/eviction counters and occupancy;
* `AsyncBatchQueue` — served queries/batches, submit-time cache hits,
  queue-depth high-water mark, flush reasons.

`MetricsServer` serves `/metrics` (the exposition) and `/healthz` on a
daemon `ThreadingHTTPServer` — enough for a scraper or a load balancer
probe without pulling in any dependency.  `rag_serve.py --metrics-port`
wires it up.
"""

from __future__ import annotations

import json
import math
import threading
from typing import Callable

__all__ = ["metrics_text", "MetricsServer"]

_PREFIX = "ann"


def _esc(v) -> str:
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt(value) -> str:
    if value is None:
        return "0"
    if isinstance(value, float):
        if math.isinf(value):
            return "+Inf" if value > 0 else "-Inf"
        if math.isnan(value):
            return "NaN"
    return repr(float(value)) if isinstance(value, float) else str(value)


class _Writer:
    def __init__(self):
        self.lines: list[str] = []
        self._typed: set[str] = set()

    def header(self, name: str, mtype: str, help_: str) -> None:
        if name in self._typed:
            return
        self._typed.add(name)
        self.lines.append(f"# HELP {name} {help_}")
        self.lines.append(f"# TYPE {name} {mtype}")

    def sample(self, name: str, labels: dict | None, value) -> None:
        if labels:
            lab = ",".join(f'{k}="{_esc(v)}"' for k, v in labels.items())
            self.lines.append(f"{name}{{{lab}}} {_fmt(value)}")
        else:
            self.lines.append(f"{name} {_fmt(value)}")

    def text(self) -> str:
        return "\n".join(self.lines) + "\n"


def _sink_metrics(w: _Writer, sink, prefix: str) -> None:
    from repro.ann.predicates import Predicate

    s = sink.stats()
    w.header(f"{prefix}_queries_total", "counter",
             "Queries recorded by the telemetry sink.")
    w.sample(f"{prefix}_queries_total", None, s["queries"])
    w.header(f"{prefix}_batches_total", "counter",
             "Executed batches recorded by the telemetry sink.")
    w.sample(f"{prefix}_batches_total", None, s["batches"])
    w.header(f"{prefix}_latency_us", "gauge",
             "Ring-derived per-query latency percentiles (µs).")
    for q, v in s["latency_us"].items():
        w.sample(f"{prefix}_latency_us", {"quantile": q}, v)
    w.header(f"{prefix}_method_queries_total", "counter",
             "Queries served per routed method.")
    for m, n in sorted(s["by_method"].items()):
        w.sample(f"{prefix}_method_queries_total", {"method": m}, n)
    w.header(f"{prefix}_cell_queries_total", "counter",
             "Queries per (method, param-setting, predicate) cell.")
    w.header(f"{prefix}_cell_latency_us_mean", "gauge",
             "Mean per-query latency per cell (µs).")
    for (m, ps, p), (n, us) in sorted(sink.cell_aggregates().items(),
                                      key=lambda kv: str(kv[0])):
        if n <= 0:
            continue
        lab = {"method": m, "ps": ps if ps is not None else "",
               "pred": Predicate(p).name}
        w.sample(f"{prefix}_cell_queries_total", lab, n)
        w.sample(f"{prefix}_cell_latency_us_mean", lab, us / n)
    w.header(f"{prefix}_shard_stage_seconds_total", "counter",
             "Per-shard stage seconds (fan-out skew).")
    w.header(f"{prefix}_shard_stage_calls_total", "counter",
             "Per-shard stage fold count.")
    for (sh, stage), (n, sec) in sorted(sink.shard_aggregates().items()):
        lab = {"shard": sh, "stage": stage}
        w.sample(f"{prefix}_shard_stage_seconds_total", lab, sec)
        w.sample(f"{prefix}_shard_stage_calls_total", lab, n)
    w.header(f"{prefix}_counter", "counter",
             "Named sink counters (stage seconds, cache notes, waits).")
    for name, val in sorted(sink.counter_values().items()):
        w.sample(f"{prefix}_counter", {"name": name}, val)


def _tracer_metrics(w: _Writer, tracer, prefix: str) -> None:
    from repro.ann.trace import BUCKET_BOUNDS_US

    t = tracer.stats()
    w.header(f"{prefix}_traces_total", "counter",
             "Finished traces, by sampling outcome.")
    for key in ("traces", "kept", "dropped", "slow", "errors"):
        w.sample(f"{prefix}_traces_total", {"outcome": key}, t[key])
    w.header(f"{prefix}_flight_size", "gauge",
             "Span trees currently held by the flight recorder.")
    w.sample(f"{prefix}_flight_size", None, t["flight_size"])
    name = f"{prefix}_span_latency_us"
    w.header(name, "histogram",
             "Per-span latency, fixed log2-µs buckets "
             "(independent of ring capacity).")
    for span_name, h in sorted(tracer.histograms().items()):
        acc = 0
        for bound, c in zip(BUCKET_BOUNDS_US, h["counts"]):
            acc += c
            le = "+Inf" if math.isinf(bound) else _fmt(bound)
            w.sample(f"{name}_bucket", {"span": span_name, "le": le}, acc)
        w.sample(f"{name}_sum", {"span": span_name}, h["sum_us"])
        w.sample(f"{name}_count", {"span": span_name}, h["count"])


def _cache_metrics(w: _Writer, cache, prefix: str) -> None:
    c = cache.stats()
    w.header(f"{prefix}_cache_events_total", "counter",
             "Semantic-cache events (hits by kind, misses, evictions).")
    for key, val in sorted(c.items()):
        if key in ("entries", "capacity", "partitions", "hit_rate"):
            continue
        w.sample(f"{prefix}_cache_events_total", {"event": key}, val)
    w.header(f"{prefix}_cache_entries", "gauge", "Cached entries.")
    w.sample(f"{prefix}_cache_entries", None, c["entries"])
    w.header(f"{prefix}_cache_capacity", "gauge", "Cache capacity.")
    w.sample(f"{prefix}_cache_capacity", None, c["capacity"])
    w.header(f"{prefix}_cache_hit_rate", "gauge",
             "Lifetime hit rate (0 when nothing probed yet).")
    w.sample(f"{prefix}_cache_hit_rate", None, c["hit_rate"] or 0.0)


def _queue_metrics(w: _Writer, queue, prefix: str) -> None:
    s = queue.stats()
    w.header(f"{prefix}_queue_queries_total", "counter",
             "Queries served through the async batch queue.")
    w.sample(f"{prefix}_queue_queries_total", None, s["queries"])
    w.header(f"{prefix}_queue_batches_total", "counter",
             "Micro-batches flushed by the queue worker.")
    w.sample(f"{prefix}_queue_batches_total", None, s["batches"])
    w.header(f"{prefix}_queue_cache_hits_total", "counter",
             "Queries answered from the cache at submit time.")
    w.sample(f"{prefix}_queue_cache_hits_total", None, s["cache_hits"])
    w.header(f"{prefix}_queue_pending", "gauge",
             "Requests currently waiting for a flush.")
    w.sample(f"{prefix}_queue_pending", None, s["pending"])
    w.header(f"{prefix}_queue_depth_high_water", "gauge",
             "Queue-depth high-water mark.")
    w.sample(f"{prefix}_queue_depth_high_water", None,
             s["max_queue_depth"])
    w.header(f"{prefix}_queue_flushes_total", "counter",
             "Flushes by trigger reason.")
    for reason, n in sorted(s["flush_reasons"].items()):
        w.sample(f"{prefix}_queue_flushes_total", {"reason": reason}, n)


def metrics_text(*, sink=None, tracer=None, cache=None, queue=None,
                 service=None, prefix: str = _PREFIX) -> str:
    """Render one Prometheus text-format snapshot of whatever surfaces
    are passed.  `service=` is a convenience: its `telemetry` and
    `tracer` attributes fill `sink`/`tracer` when those are omitted
    (and a `SemanticResultCache` passed as `service` fills `cache`)."""
    if service is not None:
        if sink is None:
            sink = getattr(service, "telemetry", None)
        if tracer is None:
            tracer = getattr(service, "tracer", None)
        if cache is None and hasattr(service, "probe_one"):
            cache = service
    w = _Writer()
    if sink is not None:
        _sink_metrics(w, sink, prefix)
    if tracer is not None:
        _tracer_metrics(w, tracer, prefix)
    if cache is not None:
        _cache_metrics(w, cache, prefix)
    if queue is not None:
        _queue_metrics(w, queue, prefix)
    if not w.lines:
        w.header(f"{prefix}_up", "gauge", "Exporter liveness.")
        w.sample(f"{prefix}_up", None, 1)
    return w.text()


class MetricsServer:
    """Daemon HTTP server exposing `/metrics` (Prometheus text) and
    `/healthz` (JSON liveness).

    Args:
        render: zero-arg callable returning the exposition text —
            typically `lambda: metrics_text(sink=..., tracer=...)`.
        host / port: bind address; port 0 picks a free port (read it
            back from `.port`).
        health: optional zero-arg callable returning a JSON-serialisable
            health payload (merged over {"status": "ok"}).
    """

    def __init__(self, render: Callable[[], str], *,
                 host: str = "127.0.0.1", port: int = 0,
                 health: Callable[[], dict] | None = None):
        from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

        outer = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802  (http.server API)
                if self.path.split("?", 1)[0] == "/metrics":
                    try:
                        body = outer.render().encode()
                    except Exception as e:   # surface, don't kill serving
                        self._reply(500, f"# render error: {e}\n".encode(),
                                    "text/plain; charset=utf-8")
                        return
                    self._reply(200, body,
                                "text/plain; version=0.0.4; charset=utf-8")
                elif self.path.split("?", 1)[0] == "/healthz":
                    payload = {"status": "ok"}
                    if outer.health is not None:
                        try:
                            payload.update(outer.health())
                        except Exception as e:
                            payload = {"status": "degraded",
                                       "error": str(e)}
                    self._reply(200, (json.dumps(payload) + "\n").encode(),
                                "application/json")
                else:
                    self._reply(404, b"not found\n",
                                "text/plain; charset=utf-8")

            def _reply(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args) -> None:   # silence access log
                pass

        self.render = render
        self.health = health
        self._srv = ThreadingHTTPServer((host, int(port)), _Handler)
        self._srv.daemon_threads = True
        self.host, self.port = self._srv.server_address[:2]
        self._thread = threading.Thread(
            target=self._srv.serve_forever, name="ann-metrics",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        self._srv.shutdown()
        self._srv.server_close()
        self._thread.join(timeout=10)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
