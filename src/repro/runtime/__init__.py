from repro.runtime.fault import StepMonitor, PreemptionHandler, elastic_reshard

__all__ = ["StepMonitor", "PreemptionHandler", "elastic_reshard"]
