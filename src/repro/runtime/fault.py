"""Fault-tolerance runtime: straggler detection, preemption handling,
elastic resharding.

At thousand-node scale the failure model is: slow hosts (stragglers),
SIGTERM preemptions (spot/maintenance), and shrink/grow events. The
training driver composes three primitives:

  * `StepMonitor` — rolling-median step-time watchdog. A step exceeding
    `factor ×` median is recorded as a straggler event; after
    `escalate_after` consecutive events the monitor recommends
    checkpoint-and-reschedule (the single-controller analogue of backup
    workers / task re-execution).
  * `PreemptionHandler` — converts SIGTERM/SIGUSR1 into a checked flag so
    the loop checkpoints and exits cleanly at the next step boundary.
  * `elastic_reshard` — re-`device_put`s a host checkpoint onto a new mesh
    (different data-axis size), enabling restart with fewer/more replicas.
"""

from __future__ import annotations

import signal
import time
from collections import deque

import jax
from jax.sharding import NamedSharding


class StepMonitor:
    def __init__(self, *, factor: float = 3.0, window: int = 32,
                 escalate_after: int = 3, deadline_s: float | None = None):
        self.factor = factor
        self.window: deque = deque(maxlen=window)
        self.escalate_after = escalate_after
        self.deadline_s = deadline_s
        self.straggler_events = 0
        self.consecutive = 0
        self._t0 = None

    def start_step(self) -> None:
        self._t0 = time.perf_counter()

    def end_step(self) -> dict:
        dt = time.perf_counter() - self._t0
        med = sorted(self.window)[len(self.window) // 2] if self.window else dt
        straggler = bool(self.window) and (
            dt > self.factor * med or
            (self.deadline_s is not None and dt > self.deadline_s))
        self.window.append(dt)
        if straggler:
            self.straggler_events += 1
            self.consecutive += 1
        else:
            self.consecutive = 0
        return {"step_time_s": dt, "median_s": med, "straggler": straggler,
                "escalate": self.consecutive >= self.escalate_after}


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGUSR1)):
        self.requested = False
        self._previous = {}
        for sig in signals:
            self._previous[sig] = signal.signal(sig, self._handle)

    def _handle(self, signum, frame):
        self.requested = True

    def restore(self) -> None:
        for sig, prev in self._previous.items():
            signal.signal(sig, prev)


def elastic_reshard(host_tree, spec_tree, mesh):
    """Place a host checkpoint onto `mesh` with `spec_tree` shardings —
    the restart path after a shrink/grow event."""
    # host_tree defines the structure; spec leaves (PartitionSpec is a
    # tuple subclass) are picked up whole at the host leaf positions.
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        host_tree, spec_tree)
