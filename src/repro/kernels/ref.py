"""Pure-jnp oracles for the Pallas kernels (bitwise-identical semantics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def predicate_mask_ref(bitmaps, qbms, pred: int):
    """bitmaps [N, W], qbms [Q, W] -> bool [Q, N]."""
    b = bitmaps[None, :, :]
    q = qbms[:, None, :]
    if pred == 0:
        return jnp.all(b == q, axis=-1)
    if pred == 1:
        return jnp.all((b & q) == q, axis=-1)
    if pred == 2:
        return jnp.any((b & q) != 0, axis=-1)
    raise ValueError(pred)


def masked_topk_ref(qvecs, qbms, base, norms, bitmaps, *, pred: int, k: int):
    """Exact masked top-k: ids [Q, k] i32 (−1 pad), dists [Q, k] f32.

    k may exceed N (a delta segment smaller than the requested width):
    the candidate axis is padded so the surplus comes back as −1/+inf."""
    scores = norms[None, :].astype(jnp.float32) - 2.0 * jnp.dot(
        qvecs, base.T, preferred_element_type=jnp.float32)
    mask = predicate_mask_ref(bitmaps, qbms, pred)
    s = jnp.where(mask, scores, jnp.inf)
    if k > s.shape[1]:
        s = jnp.concatenate(
            [s, jnp.full((s.shape[0], k - s.shape[1]), jnp.inf, s.dtype)],
            axis=1)
    neg, idx = jax.lax.top_k(-s, k)
    ids = jnp.where(jnp.isinf(neg), -1, idx).astype(jnp.int32)
    return ids, -neg


def selectivity_ref(qbms, bitmaps, *, pred: int):
    return jnp.sum(predicate_mask_ref(bitmaps, qbms, pred),
                   axis=1).astype(jnp.int32)


def fused_live_topk_ref(qvecs, qbms, cand_ids, cand_dists, dvec, dnorms,
                        dbm, base_n, tomb, *, pred: int, k: int):
    """Oracle for the fused live read: tombstone-mask the routed base
    candidates, brute-force the delta rows (global id = base_n + row),
    concatenate base-first (ties resolve to base, matching the kernel's
    fold order) and extract the k smallest. `tomb` is bool [n_total]."""
    nd = dvec.shape[0]
    d_ids = base_n + jnp.arange(nd, dtype=jnp.int32)
    scores = dnorms[None, :].astype(jnp.float32) - 2.0 * jnp.dot(
        qvecs, dvec.T, preferred_element_type=jnp.float32)
    mask = predicate_mask_ref(dbm, qbms, pred)
    live = ~tomb[jnp.clip(d_ids, 0, tomb.shape[0] - 1)]
    s = jnp.where(mask & live[None, :], scores, jnp.inf)

    ci = cand_ids.astype(jnp.int32)
    dead = tomb[jnp.clip(ci, 0, tomb.shape[0] - 1)] | (ci < 0)
    cd = jnp.where(dead | ~jnp.isfinite(cand_dists), jnp.inf, cand_dists)

    q = qvecs.shape[0]
    all_d = jnp.concatenate([cd, s], axis=1)
    all_i = jnp.concatenate(
        [jnp.where(jnp.isinf(cd), -1, ci),
         jnp.broadcast_to(d_ids[None, :], (q, nd))], axis=1)
    if k > all_d.shape[1]:
        pad = k - all_d.shape[1]
        all_d = jnp.concatenate(
            [all_d, jnp.full((q, pad), jnp.inf, all_d.dtype)], axis=1)
        all_i = jnp.concatenate(
            [all_i, jnp.full((q, pad), -1, all_i.dtype)], axis=1)
    neg, sel = jax.lax.top_k(-all_d, k)
    out_i = jnp.take_along_axis(all_i, sel, axis=1)
    out_i = jnp.where(jnp.isinf(neg), -1, out_i).astype(jnp.int32)
    return out_i, -neg


def merge_topk_ref(ids, dists, *, k: int | None = None):
    """Cross-shard merge oracle: flatten [S, Q, K] candidates to
    [Q, S*K] and re-extract the k smallest. Invalid slots (id −1 or
    non-finite dist) come back as id −1 / dist +inf, trailing. k may
    exceed S*K — the candidate axis is padded with invalid slots."""
    s, q, kk = ids.shape
    if k is None:
        k = kk
    i_all = jnp.moveaxis(ids, 0, 1).reshape(q, s * kk)
    d_all = jnp.moveaxis(dists, 0, 1).reshape(q, s * kk)
    d_all = jnp.where((i_all < 0) | ~jnp.isfinite(d_all), jnp.inf, d_all)
    if k > s * kk:
        d_all = jnp.concatenate(
            [d_all, jnp.full((q, k - s * kk), jnp.inf, d_all.dtype)], axis=1)
        i_all = jnp.concatenate(
            [i_all, jnp.full((q, k - s * kk), -1, i_all.dtype)], axis=1)
    neg, sel = jax.lax.top_k(-d_all, k)
    out_ids = jnp.take_along_axis(i_all, sel, axis=1)
    out_ids = jnp.where(jnp.isinf(neg), -1, out_ids).astype(jnp.int32)
    return out_ids, -neg
