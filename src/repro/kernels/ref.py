"""Pure-jnp oracles for the Pallas kernels (bitwise-identical semantics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def predicate_mask_ref(bitmaps, qbms, pred: int):
    """bitmaps [N, W], qbms [Q, W] -> bool [Q, N]."""
    b = bitmaps[None, :, :]
    q = qbms[:, None, :]
    if pred == 0:
        return jnp.all(b == q, axis=-1)
    if pred == 1:
        return jnp.all((b & q) == q, axis=-1)
    if pred == 2:
        return jnp.any((b & q) != 0, axis=-1)
    raise ValueError(pred)


def masked_topk_ref(qvecs, qbms, base, norms, bitmaps, *, pred: int, k: int):
    """Exact masked top-k: ids [Q, k] i32 (−1 pad), dists [Q, k] f32."""
    scores = norms[None, :].astype(jnp.float32) - 2.0 * jnp.dot(
        qvecs, base.T, preferred_element_type=jnp.float32)
    mask = predicate_mask_ref(bitmaps, qbms, pred)
    s = jnp.where(mask, scores, jnp.inf)
    neg, idx = jax.lax.top_k(-s, k)
    ids = jnp.where(jnp.isinf(neg), -1, idx).astype(jnp.int32)
    return ids, -neg


def selectivity_ref(qbms, bitmaps, *, pred: int):
    return jnp.sum(predicate_mask_ref(bitmaps, qbms, pred),
                   axis=1).astype(jnp.int32)
