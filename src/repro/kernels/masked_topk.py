"""Pallas TPU kernel: fused predicate-mask + distance + running top-k.

This is the hot loop of filtered brute-force scan (Pre-filter and the
per-shard step of the distributed search). The TPU-native design:

  * grid = (query tiles, base blocks) with
    ``dimension_semantics=("parallel", "arbitrary")`` — query tiles are
    independent, base blocks are a sequential reduction axis;
  * each step loads a [BQ, D] query tile and a [BN, D] base block into
    VMEM, computes the score block ||v||² − 2·v·q on the MXU
    (`jnp.dot` with f32 accumulation),
  * evaluates the label predicate word-parallel on the VPU directly on the
    packed uint32 bitmap block (no [Q, N, W] temporary),
  * and folds the block into a **running top-k carried in VMEM scratch**:
    the carry [BQ, k] from previous base blocks is concatenated with the
    masked score block and re-extracted by k-step min-extraction, so the
    kernel emits final [Q, k] dists/ids directly — no [n_blocks, Q, k]
    HBM intermediate and no host/XLA cross-block merge.

The same VMEM-carried accumulation (factored as `_fold_topk`) also powers
`merge_topk_accum`, the cross-shard reduction of `ShardedFilteredIndex`:
per-shard [S, Q, K] top-k candidates are folded shard by shard into one
global [Q, k] result, with shards as the sequential grid axis.

The legacy per-block variant (`masked_topk_blocks`) is kept as a parity
reference for tests. VMEM budget at the default BQ=128, BN=1024, D≤128,
W≤64: ~1.6 MB — comfortably inside 16 MB v5e VMEM with double-buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BN = 1024
PAD_SCORE = 3.0e38  # sentinel for masked-out candidates (finite: inf breaks min-extraction ties)


def _predicate_mask_block(bm_blk, qbm_blk, pred: int):
    """bm_blk [BN, W] uint32, qbm_blk [BQ, W] uint32 -> bool [BQ, BN]."""
    bq, w = qbm_blk.shape
    bn = bm_blk.shape[0]
    if pred == 0:      # EQUALITY
        acc = jnp.ones((bq, bn), dtype=jnp.bool_)
        for i in range(w):
            acc &= bm_blk[None, :, i] == qbm_blk[:, i, None]
        return acc
    if pred == 1:      # AND (containment)
        acc = jnp.ones((bq, bn), dtype=jnp.bool_)
        for i in range(w):
            qw = qbm_blk[:, i, None]
            acc &= (bm_blk[None, :, i] & qw) == qw
        return acc
    if pred == 2:      # OR (overlap)
        acc = jnp.zeros((bq, bn), dtype=jnp.bool_)
        for i in range(w):
            acc |= (bm_blk[None, :, i] & qbm_blk[:, i, None]) != 0
        return acc
    raise ValueError(pred)


def _masked_scores(q_ref, qbm_ref, base_ref, norms_ref, bm_ref, pred: int):
    """Score block [BQ, BN] with masked-out candidates at PAD_SCORE."""
    scores = norms_ref[...][None, :].astype(jnp.float32) - 2.0 * jnp.dot(
        q_ref[...], base_ref[...].T,
        preferred_element_type=jnp.float32)    # [BQ, BN] on MXU
    mask = _predicate_mask_block(bm_ref[...], qbm_ref[...], pred)
    return jnp.where(mask, scores, PAD_SCORE)


def _fold_topk(accd_ref, acci_ref, blk_d, blk_i, k: int) -> None:
    """Fold a candidate block into the running top-k carried in VMEM.

    `accd_ref`/`acci_ref` are [BQ, k] VMEM scratch holding the carry from
    previous blocks; `blk_d`/`blk_i` are the new [BQ, C] masked score/id
    block (PAD_SCORE / −1 at invalid slots). The carry and the block are
    concatenated and re-extracted by k-step min-extraction, leaving the
    scratch holding the merged top-k. Shared by the base-block reduction
    (`_accum_kernel`) and the cross-shard merge (`_merge_kernel`).
    """
    cand_d = jnp.concatenate([accd_ref[...], blk_d], axis=1)   # [BQ, k+C]
    cand_i = jnp.concatenate([acci_ref[...], blk_i], axis=1)
    bq, c = cand_d.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, c), 1)
    for i in range(k):                      # k-step min extraction in VMEM
        m = jnp.min(cand_d, axis=1)
        am = jnp.argmin(cand_d, axis=1).astype(jnp.int32)
        sel = col == am[:, None]
        picked = jnp.sum(jnp.where(sel, cand_i, 0), axis=1)
        accd_ref[:, i] = m
        acci_ref[:, i] = jnp.where(m >= PAD_SCORE, -1, picked)
        cand_d = jnp.where(sel, PAD_SCORE, cand_d)


def _accum_kernel(q_ref, qbm_ref, base_ref, norms_ref, bm_ref,
                  outd_ref, outi_ref, accd_ref, acci_ref, *,
                  pred: int, k: int, bn: int):
    """Running-top-k kernel body: carry [BQ, k] across the nb grid axis in
    VMEM scratch, write [BQ, k] outputs once on the last base block."""
    pid_n = pl.program_id(1)

    @pl.when(pid_n == 0)
    def _init():
        accd_ref[...] = jnp.full_like(accd_ref, PAD_SCORE)
        acci_ref[...] = jnp.full_like(acci_ref, -1)

    s = _masked_scores(q_ref, qbm_ref, base_ref, norms_ref, bm_ref, pred)
    bq = s.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, bn), 1)
    ids_blk = jnp.where(s >= PAD_SCORE, -1, col + pid_n * bn)
    _fold_topk(accd_ref, acci_ref, s, ids_blk, k)

    @pl.when(pid_n == pl.num_programs(1) - 1)
    def _write():
        outd_ref[...] = accd_ref[...]
        outi_ref[...] = acci_ref[...]


def masked_topk_accum(qvecs, qbms, base, norms, bitmaps, *, pred: int,
                      k: int, bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                      interpret: bool = False):
    """Raw pallas_call: VMEM-accumulated running top-k over base blocks.

    qvecs [Q, D] (Q % bq == 0), base [N, D] (N % bn == 0), qbms [Q, W],
    bitmaps [N, W]. Output: dists [Q, k] f32, ids [Q, k] i32 — final,
    no per-block intermediate.
    """
    q, d = qvecs.shape
    n, w = bitmaps.shape
    assert q % bq == 0 and n % bn == 0, (q, bq, n, bn)
    grid = (q // bq, n // bn)
    kernel = functools.partial(_accum_kernel, pred=pred, k=k, bn=bn)
    outd, outi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bq, w), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bn, d), lambda qt, nb: (nb, 0)),
            pl.BlockSpec((bn,), lambda qt, nb: (nb,)),
            pl.BlockSpec((bn, w), lambda qt, nb: (nb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bq, k), lambda qt, nb: (qt, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qvecs, qbms, base, norms, bitmaps)
    return outd, outi


# ---------------------------------------------------------------------------
# cross-shard top-k merge — the reduction step of ShardedFilteredIndex
# ---------------------------------------------------------------------------

def _merge_kernel(d_ref, i_ref, outd_ref, outi_ref, accd_ref, acci_ref, *,
                  k: int):
    """Fold one shard's [BQ, K] candidate block into the VMEM carry; write
    the merged [BQ, k] once on the last shard. Same accumulation pattern
    as `_accum_kernel`, with shards as the sequential reduction axis."""
    pid_s = pl.program_id(1)

    @pl.when(pid_s == 0)
    def _init():
        accd_ref[...] = jnp.full_like(accd_ref, PAD_SCORE)
        acci_ref[...] = jnp.full_like(acci_ref, -1)

    _fold_topk(accd_ref, acci_ref, d_ref[0], i_ref[0], k)

    @pl.when(pid_s == pl.num_programs(1) - 1)
    def _write():
        outd_ref[...] = accd_ref[...]
        outi_ref[...] = acci_ref[...]


def merge_topk_accum(dists, ids, *, k: int, bq: int = DEFAULT_BQ,
                     interpret: bool = False):
    """Raw pallas_call: merge per-shard top-k candidates into a global
    top-k, carrying the running result in VMEM scratch across the shard
    grid axis.

    dists [S, Q, K] f32 (PAD_SCORE at invalid slots), ids [S, Q, K] i32
    (−1 at invalid slots; already globalised — ids must be disjoint across
    shards), Q % bq == 0, k <= K. Output: dists [Q, k] f32, ids [Q, k]
    i32 — the k smallest candidates per query over all S·K slots.
    """
    s, q, kk = dists.shape
    assert q % bq == 0 and k <= kk, (q, bq, k, kk)
    grid = (q // bq, s)
    kernel = functools.partial(_merge_kernel, k=k)
    outd, outi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, kk), lambda qt, sh: (sh, qt, 0)),
            pl.BlockSpec((1, bq, kk), lambda qt, sh: (sh, qt, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda qt, sh: (qt, 0)),
            pl.BlockSpec((bq, k), lambda qt, sh: (qt, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(dists, ids)
    return outd, outi


# ---------------------------------------------------------------------------
# fused live search — base candidates + delta scan + tombstones, one launch
# ---------------------------------------------------------------------------

def _tombstone_bits(tomb_ref, ids):
    """Packed-tombstone lookup: ids [...] i32 global row ids -> bool dead.

    `tomb_ref` is [TW] uint32 with bit ``r & 31`` of word ``r >> 5`` set for
    dead row r (numpy ``packbits(bitorder='little')`` layout). Ids are
    clipped into range before the gather: out-of-range ids (−1 pads,
    sentinel rows past the watermark) read an arbitrary bit, which is
    harmless because their score is already PAD_SCORE."""
    tw = tomb_ref.shape[0]
    safe = jnp.clip(ids, 0, tw * 32 - 1)
    words = jnp.take(tomb_ref[...], safe >> 5, axis=0)
    bit = jnp.right_shift(words, (safe & 31).astype(jnp.uint32))
    return (bit & jnp.uint32(1)) != 0


def _fused_live_kernel(q_ref, qbm_ref, candd_ref, candi_ref, dvec_ref,
                       dnorm_ref, dbm_ref, did_ref, tomb_ref,
                       outd_ref, outi_ref, accd_ref, acci_ref, *,
                       pred: int, k: int):
    """Single-launch live read: fold the routed base candidates and the
    brute-force delta scan into one VMEM-carried running top-k.

    Grid = (query tiles, delta blocks). On the first delta block the base
    candidate set [BQ, KB] is tombstone-masked in-kernel (packed-word
    gather — no host mask) and folded into the freshly initialised carry;
    every step then scores one [BN, D] delta block, masks it by predicate
    AND tombstone, and folds it through the same `_fold_topk` accumulator.
    The final [Q, k] is written once on the last block — no [S, Q, K] HBM
    intermediate, no host merge. Because the base carry is folded before
    any delta block, score ties resolve to base rows, matching the
    staged path's merge order exactly."""
    pid_n = pl.program_id(1)

    @pl.when(pid_n == 0)
    def _init():
        accd_ref[...] = jnp.full_like(accd_ref, PAD_SCORE)
        acci_ref[...] = jnp.full_like(acci_ref, -1)
        cd = candd_ref[...]
        ci = candi_ref[...]
        bad = (ci < 0) | _tombstone_bits(tomb_ref, ci) | (cd >= PAD_SCORE)
        _fold_topk(accd_ref, acci_ref,
                   jnp.where(bad, PAD_SCORE, cd),
                   jnp.where(bad, -1, ci), k)

    s = _masked_scores(q_ref, qbm_ref, dvec_ref, dnorm_ref, dbm_ref, pred)
    ids_row = did_ref[...]                       # [BN] i32 global ids, −1 pad
    dead = _tombstone_bits(tomb_ref, ids_row[None, :]) | (ids_row[None, :] < 0)
    s = jnp.where(dead, PAD_SCORE, s)
    ids_blk = jnp.where(s >= PAD_SCORE, -1,
                        jnp.broadcast_to(ids_row[None, :], s.shape))
    _fold_topk(accd_ref, acci_ref, s, ids_blk, k)

    @pl.when(pid_n == pl.num_programs(1) - 1)
    def _write():
        outd_ref[...] = accd_ref[...]
        outi_ref[...] = acci_ref[...]


def fused_live_accum(qvecs, qbms, cand_dists, cand_ids, dvec, dnorms, dbm,
                     delta_ids, tomb_words, *, pred: int, k: int,
                     bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                     interpret: bool = False):
    """Raw pallas_call for the fused live read.

    qvecs [Q, D] (Q % bq == 0), cand_dists/cand_ids [Q, KB] routed base
    candidates (global ids, −1/PAD at invalid slots), dvec [ND, D]
    (ND % bn == 0) delta mirror with dnorms [ND] (PAD_SCORE at sentinel
    rows), dbm [ND, W], delta_ids [ND] i32 global ids (−1 at pads),
    tomb_words [TW] uint32 packed tombstones covering base + delta rows.
    Output: dists [Q, k] f32, ids [Q, k] i32 — final merged live top-k.
    """
    q, d = qvecs.shape
    nd, w = dbm.shape
    kb = cand_ids.shape[1]
    tw = tomb_words.shape[0]
    assert q % bq == 0 and nd % bn == 0, (q, bq, nd, bn)
    grid = (q // bq, nd // bn)
    kernel = functools.partial(_fused_live_kernel, pred=pred, k=k)
    outd, outi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bq, w), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bq, kb), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bq, kb), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bn, d), lambda qt, nb: (nb, 0)),
            pl.BlockSpec((bn,), lambda qt, nb: (nb,)),
            pl.BlockSpec((bn, w), lambda qt, nb: (nb, 0)),
            pl.BlockSpec((bn,), lambda qt, nb: (nb,)),
            pl.BlockSpec((tw,), lambda qt, nb: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bq, k), lambda qt, nb: (qt, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qvecs, qbms, cand_dists, cand_ids, dvec, dnorms, dbm, delta_ids,
      tomb_words)
    return outd, outi


# ---------------------------------------------------------------------------
# legacy per-block variant — kept as the parity reference for tests
# ---------------------------------------------------------------------------

def _block_kernel(q_ref, qbm_ref, base_ref, norms_ref, bm_ref,
                  outd_ref, outi_ref, *, pred: int, k: int, bn: int):
    pid_n = pl.program_id(1)
    s = _masked_scores(q_ref, qbm_ref, base_ref, norms_ref, bm_ref, pred)
    bq = s.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, bn), 1)
    base_id = pid_n * bn
    for i in range(k):                      # k-step min extraction in VMEM
        m = jnp.min(s, axis=1)
        am = jnp.argmin(s, axis=1).astype(jnp.int32)
        outd_ref[0, :, i] = m
        outi_ref[0, :, i] = jnp.where(m >= PAD_SCORE, -1, am + base_id)
        s = jnp.where(col == am[:, None], PAD_SCORE, s)


def masked_topk_blocks(qvecs, qbms, base, norms, bitmaps, *, pred: int,
                       k: int, bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                       interpret: bool = False):
    """Raw pallas_call: returns per-(base-block) top-k (legacy path).

    qvecs [Q, D] (Q % bq == 0), base [N, D] (N % bn == 0), qbms [Q, W],
    bitmaps [N, W]. Output: dists [NB, Q, k] f32, ids [NB, Q, k] i32.
    """
    q, d = qvecs.shape
    n, w = bitmaps.shape
    assert q % bq == 0 and n % bn == 0, (q, bq, n, bn)
    n_blocks = n // bn
    grid = (q // bq, n_blocks)
    kernel = functools.partial(_block_kernel, pred=pred, k=k, bn=bn)
    outd, outi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bq, w), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bn, d), lambda qt, nb: (nb, 0)),
            pl.BlockSpec((bn,), lambda qt, nb: (nb,)),
            pl.BlockSpec((bn, w), lambda qt, nb: (nb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, k), lambda qt, nb: (nb, qt, 0)),
            pl.BlockSpec((1, bq, k), lambda qt, nb: (nb, qt, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, q, k), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, q, k), jnp.int32),
        ],
        interpret=interpret,
    )(qvecs, qbms, base, norms, bitmaps)
    return outd, outi
