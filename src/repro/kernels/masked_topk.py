"""Pallas TPU kernel: fused predicate-mask + distance + per-block top-k.

This is the hot loop of filtered brute-force scan (Pre-filter and the
per-shard step of the distributed search). The TPU-native design:

  * grid = (query tiles, base blocks);
  * each step loads a [BQ, D] query tile and a [BN, D] base block into
    VMEM, computes the score block ||v||² − 2·v·q on the MXU
    (`jnp.dot` with f32 accumulation),
  * evaluates the label predicate word-parallel on the VPU directly on the
    packed uint32 bitmap block (no [Q, N, W] temporary),
  * and extracts the block-local top-k by k-step min-extraction in VMEM
    (k is small; this avoids any cross-block sort).

Per-block [BQ, k] results land in HBM; the tiny cross-block merge happens
in the jitted wrapper (`ops.masked_topk`). VMEM budget at the default
BQ=128, BN=1024, D≤128, W≤64: ~1.6 MB — comfortably inside 16 MB v5e VMEM
with double-buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BN = 1024
PAD_SCORE = 3.0e38  # sentinel for masked-out candidates (finite: inf breaks min-extraction ties)


def _predicate_mask_block(bm_blk, qbm_blk, pred: int):
    """bm_blk [BN, W] uint32, qbm_blk [BQ, W] uint32 -> bool [BQ, BN]."""
    bq, w = qbm_blk.shape
    bn = bm_blk.shape[0]
    if pred == 0:      # EQUALITY
        acc = jnp.ones((bq, bn), dtype=jnp.bool_)
        for i in range(w):
            acc &= bm_blk[None, :, i] == qbm_blk[:, i, None]
        return acc
    if pred == 1:      # AND (containment)
        acc = jnp.ones((bq, bn), dtype=jnp.bool_)
        for i in range(w):
            qw = qbm_blk[:, i, None]
            acc &= (bm_blk[None, :, i] & qw) == qw
        return acc
    if pred == 2:      # OR (overlap)
        acc = jnp.zeros((bq, bn), dtype=jnp.bool_)
        for i in range(w):
            acc |= (bm_blk[None, :, i] & qbm_blk[:, i, None]) != 0
        return acc
    raise ValueError(pred)


def _kernel(q_ref, qbm_ref, base_ref, norms_ref, bm_ref,
            outd_ref, outi_ref, *, pred: int, k: int, bn: int):
    pid_n = pl.program_id(1)
    q = q_ref[...]
    base = base_ref[...]
    scores = norms_ref[...][None, :].astype(jnp.float32) - 2.0 * jnp.dot(
        q, base.T, preferred_element_type=jnp.float32)    # [BQ, BN] on MXU
    mask = _predicate_mask_block(bm_ref[...], qbm_ref[...], pred)
    s = jnp.where(mask, scores, PAD_SCORE)
    bq = s.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, bn), 1)
    base_id = pid_n * bn
    for i in range(k):                      # k-step min extraction in VMEM
        m = jnp.min(s, axis=1)
        am = jnp.argmin(s, axis=1).astype(jnp.int32)
        outd_ref[0, :, i] = m
        outi_ref[0, :, i] = jnp.where(m >= PAD_SCORE, -1, am + base_id)
        s = jnp.where(col == am[:, None], PAD_SCORE, s)


def masked_topk_blocks(qvecs, qbms, base, norms, bitmaps, *, pred: int,
                       k: int, bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                       interpret: bool = False):
    """Raw pallas_call: returns per-(base-block) top-k.

    qvecs [Q, D] (Q % bq == 0), base [N, D] (N % bn == 0), qbms [Q, W],
    bitmaps [N, W]. Output: dists [NB, Q, k] f32, ids [NB, Q, k] i32.
    """
    q, d = qvecs.shape
    n, w = bitmaps.shape
    assert q % bq == 0 and n % bn == 0, (q, bq, n, bn)
    n_blocks = n // bn
    grid = (q // bq, n_blocks)
    kernel = functools.partial(_kernel, pred=pred, k=k, bn=bn)
    outd, outi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bq, w), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bn, d), lambda qt, nb: (nb, 0)),
            pl.BlockSpec((bn,), lambda qt, nb: (nb,)),
            pl.BlockSpec((bn, w), lambda qt, nb: (nb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, k), lambda qt, nb: (nb, qt, 0)),
            pl.BlockSpec((1, bq, k), lambda qt, nb: (nb, qt, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, q, k), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, q, k), jnp.int32),
        ],
        interpret=interpret,
    )(qvecs, qbms, base, norms, bitmaps)
    return outd, outi
