"""Jitted wrappers around the Pallas kernels: padding to block multiples,
sentinel cleanup, CPU interpret-mode fallback.

`masked_topk` calls the VMEM-accumulating kernel, which emits final [Q, k]
dists/ids directly — there is no [n_blocks, Q, k] HBM intermediate and no
cross-block merge here. The legacy per-block kernel + merge survives as
`masked_topk_multiblock` purely as a parity reference for tests."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import masked_topk as mk
from repro.kernels import bitmap_filter as bf


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x, mult, fill=0):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad,) + x.shape[1:], fill, dtype=x.dtype)], axis=0)


def _pad_case(qvecs, qbms, base, norms, bitmaps, bq, bn):
    """Pad all operands to block multiples; padded base rows get sentinel
    norms (never selected: zero vectors + PAD norm give exactly PAD score)."""
    q = qvecs.shape[0]
    bq_eff = min(bq, max(8, q))
    return (_pad_rows(qvecs, bq_eff), _pad_rows(qbms, bq_eff),
            _pad_rows(base, bn), _pad_rows(norms, bn, fill=mk.PAD_SCORE),
            _pad_rows(bitmaps, bn), bq_eff)


@partial(jax.jit, static_argnames=("pred", "k", "bq", "bn", "interpret"))
def masked_topk(qvecs, qbms, base, norms, bitmaps, *, pred: int, k: int,
                bq: int = mk.DEFAULT_BQ, bn: int = mk.DEFAULT_BN,
                interpret: bool | None = None):
    """Fused filtered brute-force top-k. Returns (ids [Q,k] i32, dists [Q,k]).

    Handles arbitrary Q/N by padding to block multiples; the kernel carries
    the running top-k across base blocks in VMEM and returns [Q, k] directly.
    """
    if interpret is None:
        interpret = not _on_tpu()
    q = qvecs.shape[0]
    n = base.shape[0]
    qv, qb, bs, nm, bm, bq_eff = _pad_case(qvecs, qbms, base, norms, bitmaps,
                                           bq, bn)
    outd, outi = mk.masked_topk_accum(
        qv, qb, bs, nm, bm, pred=pred, k=k, bq=bq_eff, bn=bn,
        interpret=interpret)
    ids, dists = outi[:q], outd[:q]
    # drop padded-row hits and sentinel scores
    bad = (ids < 0) | (ids >= n) | (dists >= mk.PAD_SCORE)
    return jnp.where(bad, -1, ids), jnp.where(bad, jnp.inf, dists)


@partial(jax.jit, static_argnames=("pred", "k", "bq", "bn", "interpret"))
def masked_topk_multiblock(qvecs, qbms, base, norms, bitmaps, *, pred: int,
                           k: int, bq: int = mk.DEFAULT_BQ,
                           bn: int = mk.DEFAULT_BN,
                           interpret: bool | None = None):
    """Legacy path: per-block [NB, Q, k] kernel output merged by
    moveaxis/reshape/top_k. Parity reference only — see `masked_topk`."""
    if interpret is None:
        interpret = not _on_tpu()
    q = qvecs.shape[0]
    n = base.shape[0]
    qv, qb, bs, nm, bm, bq_eff = _pad_case(qvecs, qbms, base, norms, bitmaps,
                                           bq, bn)
    outd, outi = mk.masked_topk_blocks(
        qv, qb, bs, nm, bm, pred=pred, k=k, bq=bq_eff, bn=bn,
        interpret=interpret)
    nb = outd.shape[0]
    qp = qv.shape[0]
    d_all = jnp.moveaxis(outd, 0, 1).reshape(qp, nb * k)
    i_all = jnp.moveaxis(outi, 0, 1).reshape(qp, nb * k)
    bad = (i_all >= n) | (i_all < 0) | (d_all >= mk.PAD_SCORE)
    d_all = jnp.where(bad, jnp.inf, d_all)
    neg, sel = jax.lax.top_k(-d_all, k)
    ids = jnp.take_along_axis(i_all, sel, axis=1)
    ids = jnp.where(jnp.isinf(neg), -1, ids)
    return ids[:q], -neg[:q]


@partial(jax.jit, static_argnames=("k", "bq", "interpret"))
def merge_topk(ids, dists, *, k: int | None = None, bq: int = mk.DEFAULT_BQ,
               interpret: bool | None = None):
    """Cross-shard top-k merge. Returns (ids [Q, k] i32, dists [Q, k] f32).

    Args:
        ids: [S, Q, K] int32 per-shard candidate ids, −1 at invalid slots.
            Ids must already be globalised (disjoint across shards).
        dists: [S, Q, K] float32 per-shard scores; +inf (or any value ≥
            `masked_topk.PAD_SCORE`) marks invalid slots alongside id −1.
        k: output width; defaults to K (merge per-shard top-K into a
            global top-K). k > K is allowed — the candidate axis is
            padded with invalid slots, so the surplus comes back as −1
            ids with +inf dists (the delta-segment path hits this when a
            segment holds fewer candidates than the requested k).
        bq: query tile size; interpret: force/suppress interpret mode
            (default: interpret off-TPU).

    The kernel carries the running [Q, k] result across the shard axis in
    VMEM scratch (same accumulation as `masked_topk`), so the merge makes
    one pass over the [S, Q, K] candidates with no [Q, S*K] reshuffle.
    S=1 skips the Pallas launch entirely: a single segment only needs the
    re-sort that pushes its invalid slots to the tail, which one XLA
    `top_k` does. Invalid outputs come back as id −1 with dist +inf.
    """
    if interpret is None:
        interpret = not _on_tpu()
    s, q, kk = ids.shape
    if k is None:
        k = kk
    d = jnp.where((ids < 0) | (dists >= mk.PAD_SCORE) | jnp.isnan(dists),
                  mk.PAD_SCORE, dists.astype(jnp.float32))
    if k > kk:
        d = jnp.concatenate(
            [d, jnp.full((s, q, k - kk), mk.PAD_SCORE, d.dtype)], axis=2)
        ids = jnp.concatenate(
            [ids, jnp.full((s, q, k - kk), -1, ids.dtype)], axis=2)
        kk = k
    if s == 1:                      # single-segment pass-through
        neg, sel = jax.lax.top_k(-d[0], k)
        out_i = jnp.take_along_axis(ids[0], sel, axis=1)
        bad = (out_i < 0) | (-neg >= mk.PAD_SCORE)
        return (jnp.where(bad, -1, out_i),
                jnp.where(bad, jnp.inf, -neg))
    bq_eff = min(bq, max(8, q))
    pad = (-q) % bq_eff
    if pad:
        d = jnp.concatenate(
            [d, jnp.full((s, pad, kk), mk.PAD_SCORE, d.dtype)], axis=1)
        ids = jnp.concatenate(
            [ids, jnp.full((s, pad, kk), -1, ids.dtype)], axis=1)
    outd, outi = mk.merge_topk_accum(d, ids, k=k, bq=bq_eff,
                                     interpret=interpret)
    outd, outi = outd[:q], outi[:q]
    bad = (outi < 0) | (outd >= mk.PAD_SCORE)
    return jnp.where(bad, -1, outi), jnp.where(bad, jnp.inf, outd)


@partial(jax.jit, static_argnames=("pred", "bq", "bn", "interpret"))
def selectivity(qbms, bitmaps, *, pred: int, bq: int = 128, bn: int = 2048,
                interpret: bool | None = None):
    """Per-query predicate match counts [Q] i32."""
    if interpret is None:
        interpret = not _on_tpu()
    q = qbms.shape[0]
    n = bitmaps.shape[0]
    bq_eff = min(bq, max(8, q))
    bn_eff = min(bn, max(256, n))
    qb = _pad_rows(qbms, bq_eff)
    bm = _pad_rows(bitmaps, bn_eff)
    counts = bf.selectivity_count(qb, bm, pred=pred, bq=bq_eff, bn=bn_eff,
                                  interpret=interpret)
    # padded base rows have all-zero bitmaps: they match EQUALITY and AND
    # (vacuous containment) iff the query label set is empty — subtract
    # that contribution exactly. OR never matches a zero bitmap.
    pad_n = bm.shape[0] - n
    if pad_n and pred in (0, 1):
        empty_q = jnp.all(qb == 0, axis=1)
        counts = counts - jnp.where(empty_q, pad_n, 0).astype(jnp.int32)
    return counts[:q]
