"""Jitted wrappers around the Pallas kernels: padding to block multiples,
sentinel cleanup, backend dispatch.

`masked_topk` calls the VMEM-accumulating kernel, which emits final [Q, k]
dists/ids directly — there is no [n_blocks, Q, k] HBM intermediate and no
cross-block merge here. The legacy per-block kernel + merge survives as
`masked_topk_multiblock` purely as a parity reference for tests.

Off TPU (``interpret=None``, the default) the top-k ops run a
**fold-identical XLA formulation** instead of the interpret-mode kernel:
the VMEM fold is a stable selection — smallest score first, ties to the
earliest-folded candidate — which is exactly `jax.lax.top_k`'s
lowest-index tie rule over the candidates laid out in fold order (base
carry first, then blocks by ascending id). The score expression is the
kernel's, so on inputs where the matmul bits agree the results are
bit-identical (the parity tests pin this on an exactly-representable
grid); on arbitrary floats the backends may differ in the last ulp of a
distance, exactly as two matmul shapes already can. Interpret mode
emulates the kernel's insertion loop per grid step at Python speed, fine
for parity tests but ~6× slower than XLA on the live read path; passing
an explicit ``interpret=True/False`` still forces the Pallas kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import masked_topk as mk
from repro.kernels import bitmap_filter as bf


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x, mult, fill=0):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad,) + x.shape[1:], fill, dtype=x.dtype)], axis=0)


def _stable_topk(all_d, all_i, k):
    """k smallest of (dists, ids) laid out in kernel fold order; ties go
    to the lowest index — `jax.lax.top_k`'s documented tie rule — which
    is exactly `_fold_topk`'s first-match argmin. Invalid slots (score >=
    PAD_SCORE or id < 0) come back as −1 ids with +inf dists, trailing."""
    q, c = all_d.shape
    if k > c:
        all_d = jnp.concatenate(
            [all_d, jnp.full((q, k - c), mk.PAD_SCORE, all_d.dtype)], axis=1)
        all_i = jnp.concatenate(
            [all_i, jnp.full((q, k - c), -1, all_i.dtype)], axis=1)
    neg, sel = jax.lax.top_k(-all_d, k)
    out_i = jnp.take_along_axis(all_i, sel, axis=1)
    bad = (out_i < 0) | (-neg >= mk.PAD_SCORE)
    return jnp.where(bad, -1, out_i), jnp.where(bad, jnp.inf, -neg)


def _masked_topk_xla(qvecs, qbms, base, norms, bitmaps, *, pred, k):
    """XLA formulation of the masked scan: same score expression and
    predicate word-loop as the kernel, one stable top_k over the rows in
    ascending-id order (= the kernel's block fold order)."""
    scores = norms[None, :].astype(jnp.float32) - 2.0 * jnp.dot(
        qvecs, base.T, preferred_element_type=jnp.float32)
    mask = mk._predicate_mask_block(bitmaps, qbms, pred)
    s = jnp.where(mask, scores, mk.PAD_SCORE)
    ids = jnp.broadcast_to(
        jnp.arange(base.shape[0], dtype=jnp.int32)[None, :], s.shape)
    return _stable_topk(s, ids, k)


def _pad_case(qvecs, qbms, base, norms, bitmaps, bq, bn):
    """Pad all operands to block multiples; padded base rows get sentinel
    norms (never selected: zero vectors + PAD norm give exactly PAD score)."""
    q = qvecs.shape[0]
    bq_eff = min(bq, max(8, q))
    return (_pad_rows(qvecs, bq_eff), _pad_rows(qbms, bq_eff),
            _pad_rows(base, bn), _pad_rows(norms, bn, fill=mk.PAD_SCORE),
            _pad_rows(bitmaps, bn), bq_eff)


@partial(jax.jit, static_argnames=("pred", "k", "bq", "bn", "interpret"))
def masked_topk(qvecs, qbms, base, norms, bitmaps, *, pred: int, k: int,
                bq: int = mk.DEFAULT_BQ, bn: int = mk.DEFAULT_BN,
                interpret: bool | None = None):
    """Fused filtered brute-force top-k. Returns (ids [Q,k] i32, dists [Q,k]).

    Handles arbitrary Q/N by padding to block multiples; the kernel carries
    the running top-k across base blocks in VMEM and returns [Q, k] directly.
    Off TPU the default is the bit-identical XLA formulation; pass an
    explicit `interpret` to force the Pallas kernel.
    """
    if interpret is None:
        if not _on_tpu():
            return _masked_topk_xla(qvecs, qbms, base, norms, bitmaps,
                                    pred=pred, k=k)
        interpret = False
    q = qvecs.shape[0]
    n = base.shape[0]
    qv, qb, bs, nm, bm, bq_eff = _pad_case(qvecs, qbms, base, norms, bitmaps,
                                           bq, bn)
    outd, outi = mk.masked_topk_accum(
        qv, qb, bs, nm, bm, pred=pred, k=k, bq=bq_eff, bn=bn,
        interpret=interpret)
    ids, dists = outi[:q], outd[:q]
    # drop padded-row hits and sentinel scores
    bad = (ids < 0) | (ids >= n) | (dists >= mk.PAD_SCORE)
    return jnp.where(bad, -1, ids), jnp.where(bad, jnp.inf, dists)


@partial(jax.jit, static_argnames=("pred", "k", "bq", "bn", "interpret"))
def masked_topk_multiblock(qvecs, qbms, base, norms, bitmaps, *, pred: int,
                           k: int, bq: int = mk.DEFAULT_BQ,
                           bn: int = mk.DEFAULT_BN,
                           interpret: bool | None = None):
    """Legacy path: per-block [NB, Q, k] kernel output merged by
    moveaxis/reshape/top_k. Parity reference only — see `masked_topk`."""
    if interpret is None:
        interpret = not _on_tpu()
    q = qvecs.shape[0]
    n = base.shape[0]
    qv, qb, bs, nm, bm, bq_eff = _pad_case(qvecs, qbms, base, norms, bitmaps,
                                           bq, bn)
    outd, outi = mk.masked_topk_blocks(
        qv, qb, bs, nm, bm, pred=pred, k=k, bq=bq_eff, bn=bn,
        interpret=interpret)
    nb = outd.shape[0]
    qp = qv.shape[0]
    d_all = jnp.moveaxis(outd, 0, 1).reshape(qp, nb * k)
    i_all = jnp.moveaxis(outi, 0, 1).reshape(qp, nb * k)
    bad = (i_all >= n) | (i_all < 0) | (d_all >= mk.PAD_SCORE)
    d_all = jnp.where(bad, jnp.inf, d_all)
    neg, sel = jax.lax.top_k(-d_all, k)
    ids = jnp.take_along_axis(i_all, sel, axis=1)
    ids = jnp.where(jnp.isinf(neg), -1, ids)
    return ids[:q], -neg[:q]


@partial(jax.jit, static_argnames=("k", "bq", "interpret"))
def merge_topk(ids, dists, *, k: int | None = None, bq: int = mk.DEFAULT_BQ,
               interpret: bool | None = None):
    """Cross-shard top-k merge. Returns (ids [Q, k] i32, dists [Q, k] f32).

    Args:
        ids: [S, Q, K] int32 per-shard candidate ids, −1 at invalid slots.
            Ids must already be globalised (disjoint across shards).
        dists: [S, Q, K] float32 per-shard scores; +inf (or any value ≥
            `masked_topk.PAD_SCORE`) marks invalid slots alongside id −1.
        k: output width; defaults to K (merge per-shard top-K into a
            global top-K). k > K is allowed — the candidate axis is
            padded with invalid slots, so the surplus comes back as −1
            ids with +inf dists (the delta-segment path hits this when a
            segment holds fewer candidates than the requested k).
        bq: query tile size; interpret: force/suppress interpret mode
            (default: interpret off-TPU).

    The kernel carries the running [Q, k] result across the shard axis in
    VMEM scratch (same accumulation as `masked_topk`), so the merge makes
    one pass over the [S, Q, K] candidates with no [Q, S*K] reshuffle.
    S=1 — and any S off TPU (`interpret=None`) — skips the Pallas launch
    entirely: the shard-major flatten is the kernel's fold order, so one
    stable XLA `top_k` reproduces the VMEM fold bit for bit. Invalid
    outputs come back as id −1 with dist +inf.
    """
    use_xla = interpret is None and not _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    s, q, kk = ids.shape
    if k is None:
        k = kk
    d = jnp.where((ids < 0) | (dists >= mk.PAD_SCORE) | jnp.isnan(dists),
                  mk.PAD_SCORE, dists.astype(jnp.float32))
    if k > kk:
        d = jnp.concatenate(
            [d, jnp.full((s, q, k - kk), mk.PAD_SCORE, d.dtype)], axis=2)
        ids = jnp.concatenate(
            [ids, jnp.full((s, q, k - kk), -1, ids.dtype)], axis=2)
        kk = k
    if s == 1 or use_xla:           # shard-major flatten = fold order
        return _stable_topk(jnp.moveaxis(d, 0, 1).reshape(q, s * kk),
                            jnp.moveaxis(ids, 0, 1).reshape(q, s * kk), k)
    bq_eff = min(bq, max(8, q))
    pad = (-q) % bq_eff
    if pad:
        d = jnp.concatenate(
            [d, jnp.full((s, pad, kk), mk.PAD_SCORE, d.dtype)], axis=1)
        ids = jnp.concatenate(
            [ids, jnp.full((s, pad, kk), -1, ids.dtype)], axis=1)
    outd, outi = mk.merge_topk_accum(d, ids, k=k, bq=bq_eff,
                                     interpret=interpret)
    outd, outi = outd[:q], outi[:q]
    bad = (outi < 0) | (outd >= mk.PAD_SCORE)
    return jnp.where(bad, -1, outi), jnp.where(bad, jnp.inf, outd)


def _fused_live_xla(qvecs, qbms, cand_ids, cand_dists, dvec, dnorms, dbm,
                    delta_ids, tomb_words, *, pred, k):
    """XLA formulation of the fused live read: same candidate cleanup,
    packed-word tombstone gather, score expression and predicate loop as
    `mk.fused_live_accum`; candidates laid out base-first then delta rows
    in mirror order (= the kernel's fold order) under one stable top_k."""
    q = qvecs.shape[0]
    ci = cand_ids.astype(jnp.int32)
    cd = jnp.where((ci < 0) | ~jnp.isfinite(cand_dists)
                   | (cand_dists >= mk.PAD_SCORE)
                   | mk._tombstone_bits(tomb_words, ci),
                   mk.PAD_SCORE, cand_dists.astype(jnp.float32))
    ci = jnp.where(cd >= mk.PAD_SCORE, -1, ci)
    scores = dnorms[None, :].astype(jnp.float32) - 2.0 * jnp.dot(
        qvecs, dvec.T, preferred_element_type=jnp.float32)
    mask = mk._predicate_mask_block(dbm, qbms, pred)
    dead = mk._tombstone_bits(tomb_words, delta_ids) | (delta_ids < 0)
    s = jnp.where(mask & ~dead[None, :], scores, mk.PAD_SCORE)
    di = jnp.broadcast_to(delta_ids[None, :], s.shape)
    return _stable_topk(jnp.concatenate([cd, s], axis=1),
                        jnp.concatenate([ci, di], axis=1), k)


def _fused_core(qvecs, qbms, cand_ids, cand_dists, dvec, dnorms, dbm,
                delta_ids, tomb_words, *, pred, k, bq, bn, interpret):
    """Shared padding/cleanup around `mk.fused_live_accum`; `interpret is
    None` (the off-TPU default) takes the XLA formulation instead."""
    if interpret is None:
        return _fused_live_xla(qvecs, qbms, cand_ids, cand_dists, dvec,
                               dnorms, dbm, delta_ids, tomb_words,
                               pred=pred, k=k)
    q = qvecs.shape[0]
    bq_eff = min(bq, max(8, q))
    qv = _pad_rows(qvecs, bq_eff)
    qb = _pad_rows(qbms, bq_eff)
    if cand_ids.shape[1] == 0:       # no base candidates: one dummy slot
        cand_ids = jnp.full((q, 1), -1, jnp.int32)
        cand_dists = jnp.full((q, 1), mk.PAD_SCORE, jnp.float32)
    cd = jnp.where((cand_ids < 0) | ~jnp.isfinite(cand_dists)
                   | (cand_dists >= mk.PAD_SCORE),
                   mk.PAD_SCORE, cand_dists.astype(jnp.float32))
    ci = jnp.where(cd >= mk.PAD_SCORE, -1, cand_ids.astype(jnp.int32))
    cd = _pad_rows(cd, bq_eff, fill=mk.PAD_SCORE)
    ci = _pad_rows(ci, bq_eff, fill=-1)
    dv = _pad_rows(dvec, bn)
    dn = _pad_rows(dnorms, bn, fill=mk.PAD_SCORE)
    db = _pad_rows(dbm, bn)
    di = _pad_rows(delta_ids, bn, fill=-1)
    tw = _pad_rows(tomb_words, 128)
    outd, outi = mk.fused_live_accum(qv, qb, cd, ci, dv, dn, db, di, tw,
                                     pred=pred, k=k, bq=bq_eff, bn=bn,
                                     interpret=interpret)
    ids, dists = outi[:q], outd[:q]
    bad = (ids < 0) | (dists >= mk.PAD_SCORE)
    return jnp.where(bad, -1, ids), jnp.where(bad, jnp.inf, dists)


@partial(jax.jit, static_argnames=("pred", "k", "bq", "bn", "interpret"))
def fused_live_topk(qvecs, qbms, cand_ids, cand_dists, dvec, dnorms, dbm,
                    base_n, tomb_words, *, pred: int, k: int,
                    bq: int = mk.DEFAULT_BQ, bn: int = mk.DEFAULT_BN,
                    interpret: bool | None = None):
    """Fused live top-k: one launch folding routed base candidates with a
    full brute-force scan of the delta mirror, tombstones applied to both
    candidate sets in-kernel.

    Args:
        cand_ids/cand_dists: [Q, KB] routed base candidates (global ids,
            −1 / +inf at invalid slots). KB may be 0.
        dvec/dnorms/dbm: delta device mirror (sentinel rows carry
            PAD_SCORE norms and never surface).
        base_n: i32 scalar — delta row r has global id base_n + r. Traced,
            so generation changes don't recompile.
        tomb_words: [TW] uint32 packed tombstones over base + delta rows
            (little-endian bit order).

    Returns (ids [Q, k] i32 with −1 pads, dists [Q, k] f32 with +inf pads);
    bit-identical to the staged base→masked_topk→merge_topk path.
    """
    if interpret is None and _on_tpu():
        interpret = False
    nd = dvec.shape[0]
    di = jnp.arange(nd, dtype=jnp.int32) + jnp.int32(base_n)
    return _fused_core(qvecs, qbms, cand_ids, cand_dists, dvec, dnorms, dbm,
                       di, tomb_words, pred=pred, k=k, bq=bq, bn=bn,
                       interpret=interpret)


@partial(jax.jit, static_argnames=("pred", "k", "bq", "bn", "interpret"))
def fused_live_topk_select(qvecs, qbms, cand_ids, cand_dists, dvec, dnorms,
                           dbm, sel, base_n, tomb_words, *, pred: int,
                           k: int, bq: int = mk.DEFAULT_BQ,
                           bn: int = mk.DEFAULT_BN,
                           interpret: bool | None = None):
    """Fused live top-k over a *selected subset* of delta rows.

    `sel` is [NS] i32 delta-local row indices (−1 pads) chosen by the
    per-chunk mini-IVF pruner; the kernel scans only the gathered rows.
    Semantically identical to `fused_live_topk` whenever the pruner's
    exact ball bound holds (rows it drops cannot enter any query's top-k).
    """
    if interpret is None and _on_tpu():
        interpret = False
    safe = jnp.maximum(sel, 0)
    dv = jnp.take(dvec, safe, axis=0)
    dn = jnp.where(sel < 0, mk.PAD_SCORE, jnp.take(dnorms, safe))
    db = jnp.take(dbm, safe, axis=0)
    di = jnp.where(sel < 0, -1, sel + jnp.int32(base_n))
    return _fused_core(qvecs, qbms, cand_ids, cand_dists, dv, dn, db,
                       di, tomb_words, pred=pred, k=k, bq=bq, bn=bn,
                       interpret=interpret)


@partial(jax.jit, static_argnames=("pred", "bq", "bn", "interpret"))
def selectivity(qbms, bitmaps, *, pred: int, bq: int = 128, bn: int = 2048,
                interpret: bool | None = None):
    """Per-query predicate match counts [Q] i32."""
    if interpret is None:
        interpret = not _on_tpu()
    q = qbms.shape[0]
    n = bitmaps.shape[0]
    bq_eff = min(bq, max(8, q))
    bn_eff = min(bn, max(256, n))
    qb = _pad_rows(qbms, bq_eff)
    bm = _pad_rows(bitmaps, bn_eff)
    counts = bf.selectivity_count(qb, bm, pred=pred, bq=bq_eff, bn=bn_eff,
                                  interpret=interpret)
    # padded base rows have all-zero bitmaps: they match EQUALITY and AND
    # (vacuous containment) iff the query label set is empty — subtract
    # that contribution exactly. OR never matches a zero bitmap.
    pad_n = bm.shape[0] - n
    if pad_n and pred in (0, 1):
        empty_q = jnp.all(qb == 0, axis=1)
        counts = counts - jnp.where(empty_q, pad_n, 0).astype(jnp.int32)
    return counts[:q]
