"""Jitted wrappers around the Pallas kernels: padding to block multiples,
cross-block merge, CPU interpret-mode fallback."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import masked_topk as mk
from repro.kernels import bitmap_filter as bf


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x, mult, fill=0):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad,) + x.shape[1:], fill, dtype=x.dtype)], axis=0)


@partial(jax.jit, static_argnames=("pred", "k", "bq", "bn", "interpret"))
def masked_topk(qvecs, qbms, base, norms, bitmaps, *, pred: int, k: int,
                bq: int = mk.DEFAULT_BQ, bn: int = mk.DEFAULT_BN,
                interpret: bool | None = None):
    """Fused filtered brute-force top-k. Returns (ids [Q,k] i32, dists [Q,k]).

    Handles arbitrary Q/N by padding to block multiples; padded base rows
    get +sentinel norms (never selected) and padded ids map back to −1.
    """
    if interpret is None:
        interpret = not _on_tpu()
    q, _ = qvecs.shape
    n = base.shape[0]
    bq_eff = min(bq, max(8, q))
    qv = _pad_rows(qvecs, bq_eff)
    qb = _pad_rows(qbms, bq_eff)
    bs = _pad_rows(base, bn)
    nm = _pad_rows(norms, bn, fill=mk.PAD_SCORE)
    bm = _pad_rows(bitmaps, bn)
    outd, outi = mk.masked_topk_blocks(
        qv, qb, bs, nm, bm, pred=pred, k=k, bq=bq_eff, bn=bn,
        interpret=interpret)
    nb = outd.shape[0]
    qp = qv.shape[0]
    d_all = jnp.moveaxis(outd, 0, 1).reshape(qp, nb * k)
    i_all = jnp.moveaxis(outi, 0, 1).reshape(qp, nb * k)
    # drop padded-row hits and sentinel scores
    bad = (i_all >= n) | (i_all < 0) | (d_all >= mk.PAD_SCORE)
    d_all = jnp.where(bad, jnp.inf, d_all)
    neg, sel = jax.lax.top_k(-d_all, k)
    ids = jnp.take_along_axis(i_all, sel, axis=1)
    ids = jnp.where(jnp.isinf(neg), -1, ids)
    return ids[:q], -neg[:q]


@partial(jax.jit, static_argnames=("pred", "bq", "bn", "interpret"))
def selectivity(qbms, bitmaps, *, pred: int, bq: int = 128, bn: int = 2048,
                interpret: bool | None = None):
    """Per-query predicate match counts [Q] i32."""
    if interpret is None:
        interpret = not _on_tpu()
    q = qbms.shape[0]
    n = bitmaps.shape[0]
    bq_eff = min(bq, max(8, q))
    bn_eff = min(bn, max(256, n))
    qb = _pad_rows(qbms, bq_eff)
    bm = _pad_rows(bitmaps, bn_eff)
    counts = bf.selectivity_count(qb, bm, pred=pred, bq=bq_eff, bn=bn_eff,
                                  interpret=interpret)
    # padded base rows have all-zero bitmaps: they match EQUALITY and AND
    # (vacuous containment) iff the query label set is empty — subtract
    # that contribution exactly. OR never matches a zero bitmap.
    pad_n = bm.shape[0] - n
    if pad_n and pred in (0, 1):
        empty_q = jnp.all(qb == 0, axis=1)
        counts = counts - jnp.where(empty_q, pad_n, 0).astype(jnp.int32)
    return counts[:q]
