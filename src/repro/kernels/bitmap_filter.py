"""Pallas TPU kernel: predicate selectivity counting over packed bitmaps.

Computes |{i : P(L_i, L_q)}| for a query batch — the router's per-query
`selectivity` feature (the paper's Roaring-bitmap step). Grid iterates base
blocks sequentially per query tile and accumulates counts in the revisited
output block (standard Pallas reduction pattern)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.masked_topk import _predicate_mask_block


def _kernel(qbm_ref, bm_ref, out_ref, *, pred: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mask = _predicate_mask_block(bm_ref[...], qbm_ref[...], pred)
    out_ref[...] += jnp.sum(mask.astype(jnp.int32), axis=1)


def selectivity_count(qbms, bitmaps, *, pred: int, bq: int = 128,
                      bn: int = 2048, interpret: bool = False):
    """qbms [Q, W], bitmaps [N, W] -> counts [Q] int32. Q%bq==0, N%bn==0."""
    q, w = qbms.shape
    n = bitmaps.shape[0]
    assert q % bq == 0 and n % bn == 0
    kernel = functools.partial(_kernel, pred=pred)
    return pl.pallas_call(
        kernel,
        grid=(q // bq, n // bn),
        in_specs=[
            pl.BlockSpec((bq, w), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bn, w), lambda qt, nb: (nb, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda qt, nb: (qt,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        interpret=interpret,
    )(qbms, bitmaps)
