"""Pallas TPU kernel: predicate selectivity counting over packed bitmaps.

Computes |{i : P(L_i, L_q)}| for a query batch — the router's per-query
`selectivity` feature (the paper's Roaring-bitmap step). Grid is
(query tiles, base blocks) with ``dimension_semantics=("parallel",
"arbitrary")``: base blocks are a sequential reduction axis whose partial
counts accumulate in VMEM scratch; the [BQ] output block is written once,
on the last base block (same block-accumulation pattern as the running
top-k in `masked_topk`)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.masked_topk import _predicate_mask_block


def _kernel(qbm_ref, bm_ref, out_ref, acc_ref, *, pred: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mask = _predicate_mask_block(bm_ref[...], qbm_ref[...], pred)
    acc_ref[...] += jnp.sum(mask.astype(jnp.int32), axis=1)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _write():
        out_ref[...] = acc_ref[...]


def selectivity_count(qbms, bitmaps, *, pred: int, bq: int = 128,
                      bn: int = 2048, interpret: bool = False):
    """qbms [Q, W], bitmaps [N, W] -> counts [Q] int32. Q%bq==0, N%bn==0."""
    q, w = qbms.shape
    n = bitmaps.shape[0]
    assert q % bq == 0 and n % bn == 0
    kernel = functools.partial(_kernel, pred=pred)
    return pl.pallas_call(
        kernel,
        grid=(q // bq, n // bn),
        in_specs=[
            pl.BlockSpec((bq, w), lambda qt, nb: (qt, 0)),
            pl.BlockSpec((bn, w), lambda qt, nb: (nb, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda qt, nb: (qt,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qbms, bitmaps)
