"""deepseek-v2-236b [arXiv:2405.04434]: MoE + MLA.

60L, d_model 5120, 128 heads, MLA kv_lora_rank=512 (+64 rope dims),
160 routed experts top-6 + 2 shared, expert d_ff 1536, vocab 102400.
Experts shard expert-parallel (160 % 16 == 0); the MLA cache stores only
c_kv[512]+k_r[64] per token — the paper-faithful KV-memory win.
Deviation: every layer is MoE (the real model's first layer is dense)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400,
    n_experts=160, n_shared_experts=2, experts_per_token=6, moe_d_ff=1536,
    use_mla=True, kv_lora_rank=512, mla_rope_dim=64,
    param_dtype="bfloat16", opt_compress=True, microbatch_seqs=1,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-v2-236b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96, vocab=512,
    n_experts=8, n_shared_experts=1, experts_per_token=2, moe_d_ff=96,
    use_mla=True, kv_lora_rank=32, mla_rope_dim=16, remat=False,
)
