"""ModelConfig + the assigned input-shape grid.

Shapes (same for every LM arch):
  train_4k    — seq 4096,  global_batch 256  (train_step)
  prefill_32k — seq 32768, global_batch 32   (serve prefill)
  decode_32k  — seq 32768 KV, global_batch 128, 1 new token (serve decode)
  long_500k   — seq 524288 KV, global_batch 1 (decode; sub-quadratic archs only)
"""

from __future__ import annotations

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec-audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # --- MLA (deepseek) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    mla_rope_dim: int = 64
    # --- SSM / hybrid ---
    ssm_state: int = 0
    block_pattern: tuple = ()     # e.g. ("slstm","mlstm",...) cycle; () = uniform
    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0       # 0 = full attention
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0          # precomputed frame/patch embeddings length
    # --- numerics / distribution ---
    norm_eps: float = 1e-5
    param_dtype: str = "float32"  # giants use bfloat16 + compressed Adam
    compute_dtype: str = "bfloat16"
    opt_compress: bool = False
    remat: bool = True
    microbatch_seqs: int = 4      # per-replica sequences per grad-accum step
    # --- capability flags ---
    sub_quadratic: bool = False   # supports long_500k decode
    has_decoder: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "whisper-medium", "chameleon-34b", "xlstm-125m", "deepseek-v2-236b",
    "grok-1-314b", "codeqwen1.5-7b", "internlm2-1.8b", "internlm2-20b",
    "qwen2-0.5b", "hymba-1.5b",
]


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.SMOKE_CONFIG


def registry() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}


def shape_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(supported, reason-if-not) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full quadratic attention: 500K-token decode unsupported (DESIGN.md §4)"
    if shape.kind == "decode" and not cfg.has_decoder:
        return False, "encoder-only architecture has no decode step"
    return True, ""
