"""grok-1-314b [hf:xai-org/grok-1]: MoE decoder.

64L, d_model 6144, 48H (GQA kv=8), 8 experts top-2 with expert d_ff
32768, vocab 131072. 8 experts on a 16-way TP axis: experts replicate
and d_ff shards (TP-in-expert), see models/moe.py."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    n_experts=8, n_shared_experts=0, experts_per_token=2, moe_d_ff=32768,
    param_dtype="bfloat16", opt_compress=True, microbatch_seqs=1,
)

SMOKE_CONFIG = ModelConfig(
    name="grok-1-314b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    n_experts=4, n_shared_experts=0, experts_per_token=2, moe_d_ff=128,
    remat=False,
)
