"""xlstm-125m [arXiv:2405.04517]: sLSTM + mLSTM blocks.

12L, d_model 768, 4 heads, vocab 50304, d_ff=0 (mixer-only blocks).
Block pattern: one sLSTM per 4 (scalar memory, truly recurrent scan),
rest mLSTM (matrix memory, chunkwise-parallel GLA — see models/ssm.py).
Recurrent O(1)-state decode => long_500k supported."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=192,
    block_pattern=("slstm", "mlstm", "mlstm", "mlstm"),
    sub_quadratic=True, microbatch_seqs=4,
)

SMOKE_CONFIG = ModelConfig(
    name="xlstm-125m-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, d_ff=0, vocab=512,
    head_dim=32, block_pattern=("slstm", "mlstm", "mlstm", "mlstm"),
    sub_quadratic=True, remat=False,
)
