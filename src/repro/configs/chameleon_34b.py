"""chameleon-34b [arXiv:2405.09818]: early-fusion VLM decoder.

48L, d_model 8192, 64H (GQA kv=8), d_ff 22016, vocab 65536 — VQ image
tokens are ordinary vocabulary ids, so the modality frontend is a stub and
the backbone is a dense decoder-only transformer."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536,
    param_dtype="bfloat16", opt_compress=True, microbatch_seqs=1,
)

SMOKE_CONFIG = ModelConfig(
    name="chameleon-34b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160, vocab=512,
    remat=False,
)
