"""qwen2-0.5b [arXiv:2407.10671]: dense decoder, GQA kv=2, QKV bias.

24L, d_model 896, 14H (GQA kv=2), d_ff 4864, vocab 151936."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151936, qkv_bias=True, microbatch_seqs=4,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-0.5b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    qkv_bias=True, remat=False,
)
