"""hymba-1.5b [arXiv:2411.13676]: hybrid — parallel attention + Mamba heads.

32L, d_model 1600, 25H (GQA kv=5), d_ff 5504, vocab 32001, ssm_state 16.
Attention uses a 1024-token sliding window (ring-buffer cache), the Mamba
path carries O(1) SSD state => long_500k supported."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, ssm_state=16, sliding_window=1024,
    sub_quadratic=True, microbatch_seqs=4,
)

SMOKE_CONFIG = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    ssm_state=8, sliding_window=8, sub_quadratic=True, remat=False,
)
