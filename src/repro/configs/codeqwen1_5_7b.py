"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B]: dense decoder (qwen1.5 arch).

32L, d_model 4096, 32H (kv=32 -> MHA), d_ff 13440, vocab 92416."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab=92416, microbatch_seqs=2,
)

SMOKE_CONFIG = ModelConfig(
    name="codeqwen1.5-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=512,
    remat=False,
)
