"""whisper-medium [arXiv:2212.04356]: enc-dec audio transformer backbone.

24 decoder + 24 encoder layers, d_model 1024, 16 heads (kv=16), d_ff 4096,
vocab 51865. The conv audio frontend is a STUB: `input_specs()` supplies
precomputed frame embeddings [B, 1500, 1024] (see shape card / DESIGN.md).
Decoder self-attn uses RoPE (deviation from learned sinusoidal; noted)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec-audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    encoder_layers=24, encoder_seq=1500,
    microbatch_seqs=4,
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-medium-smoke", family="encdec-audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    encoder_layers=2, encoder_seq=16, remat=False,
)
