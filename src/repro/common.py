"""Shared utilities: artifact paths, persistent compilation cache, timers."""

from __future__ import annotations

import contextlib
import os
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def artifacts_dir(*sub: str) -> str:
    d = os.path.join(os.environ.get("REPRO_ARTIFACTS", os.path.join(_REPO_ROOT, "artifacts")), *sub)
    os.makedirs(d, exist_ok=True)
    return d


_CACHE_ENABLED = False


def enable_compilation_cache() -> None:
    """Persistent XLA compilation cache — big win for repeated CLI runs."""
    global _CACHE_ENABLED
    if _CACHE_ENABLED:
        return
    import jax

    jax.config.update("jax_compilation_cache_dir", artifacts_dir("jax_cache"))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
    _CACHE_ENABLED = True


@contextlib.contextmanager
def timer():
    """`with timer() as t: ...; t()` -> elapsed seconds."""
    t0 = time.perf_counter()
    elapsed = [0.0]
    yield lambda: elapsed[0]
    elapsed[0] = time.perf_counter() - t0
