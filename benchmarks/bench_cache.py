"""Semantic result cache: hit-rate + served-latency under Zipfian replay.

``run`` fronts a routed `RouterService` with `SemanticResultCache` and
replays a Zipf-distributed request stream over a fixed query pool — the
repetitive-traffic shape the cache exists for. Recorded per size:

* ``hit_rate`` — exact+semantic hits / requests over the whole replay;
* ``served_p50_us`` / ``served_p90_us`` — per-request latency of the
  cache-fronted service across the replay (hits and misses mixed, the
  number a caller actually sees);
* ``hit_us`` — exact-key hit-path latency (probe + freshness check,
  no routing, no search), best-of-rounds;
* ``routed_us`` — the same single query through the full routed search,
  best-of-rounds;
* ``speedup`` — routed_us / hit_us, gated **absolutely** by ``--check``
  (CACHE_SPEEDUP_MIN): the exact-key hit path must stay ≥5× cheaper
  than a routed search, or the cache isn't paying for its admission
  bookkeeping.

Rounds interleave hit/routed measurements so a noisy neighbour can't
bias the ratio.
"""

from __future__ import annotations

import numpy as np

from repro.ann.cache import SemanticResultCache
from repro.ann.index import FilteredIndex, QueryBatch
from repro.ann.predicates import Predicate
from repro.ann.registry import candidate_methods
from repro.ann.service import RouterService
from repro.ann.telemetry import TelemetrySink, constant_router
from repro.core import features as F
from repro.core.table import BenchmarkTable
from repro.data.ann_synth import DatasetSpec, make_queries, synthesize

from benchmarks.common import emit, timeit_us

_SPEC = DatasetSpec("bench_cache", 8192, 32, 60, 8, 16,
                    1.3, 2.0, 0.5, 0.3, 17)
_SMOKE_SPEC = DatasetSpec("bench_cache_smoke", 2048, 32, 60, 8, 16,
                          1.3, 2.0, 0.5, 0.3, 17)
_ROUNDS = 5
_ZIPF_S = 1.1


def _dense_table(ds_name: str, methods: list, seed: int = 0):
    rng = np.random.default_rng(seed)
    cand = candidate_methods()
    table = BenchmarkTable.new()
    for m in methods:
        for s in cand[m].param_settings():
            for pt in range(3):
                table.add(ds_name, pt, m, s.ps_id,
                          rng.uniform(0.91, 1.0), rng.uniform(100, 2000))
    return table


def _zipf_stream(pool: int, requests: int, seed: int) -> np.ndarray:
    """Zipf(s)-distributed pool indices — rank r served ∝ 1/r^s."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, pool + 1, dtype=np.float64) ** _ZIPF_S
    return rng.choice(pool, size=requests, p=p / p.sum())


def run(verbose=True, smoke: bool = False, requests: int | None = None):
    spec, requests = ((_SMOKE_SPEC, requests or 512) if smoke
                      else (_SPEC, requests or 2048))
    pool_n = 128 if smoke else 256
    ds = synthesize(spec)
    methods = ["labelnav", "postfilter", "sieve", "ivf_gamma", "fvamana"]
    table = _dense_table(ds.name, methods)
    router = constant_router(F.MINIMAL_FEATURES, methods, table)
    qs = make_queries(ds, Predicate.AND, pool_n, seed=5)
    stream = _zipf_stream(pool_n, requests, seed=9)
    rows = []
    with FilteredIndex(ds) as fx:
        sink = TelemetrySink(capacity=4096, reservoir=64, seed=7)
        svc = RouterService(fx, router, t=0.9, telemetry=sink)
        cache = SemanticResultCache(svc, threshold=0.98,
                                    capacity=pool_n * 2)
        one = QueryBatch(qs.vectors[:1], qs.bitmaps[:1],
                         Predicate.AND, 10)
        svc.search(one)                         # warm-up + compile
        cache.search(one)                       # seed the hit path

        # Zipfian replay: per-request latency through the fronted
        # service. A quarter of the requests are near-duplicates (tiny
        # vector jitter) rather than byte-identical repeats — they miss
        # the exact key and exercise the cosine/semantic path.
        jrng = np.random.default_rng(33)
        scale = 1e-3 * float(np.median(
            np.linalg.norm(qs.vectors, axis=1))) / np.sqrt(ds.dim)
        jitter = (scale * jrng.normal(0, 1, (requests, ds.dim))
                  ).astype(np.float32)
        near = jrng.random(requests) < 0.25
        lat_us = np.empty(requests, dtype=np.float64)
        import time as _time
        for i, qi in enumerate(stream):
            vec = qs.vectors[qi:qi + 1]
            if near[i]:
                vec = vec + jitter[i:i + 1]
            b = QueryBatch(vec, qs.bitmaps[qi:qi + 1],
                           Predicate.AND, 10)
            t0 = _time.perf_counter()
            cache.search(b)
            lat_us[i] = (_time.perf_counter() - t0) * 1e6
        st = cache.stats()
        hit_rate = ((st["hits_exact"] + st["hits_semantic"])
                    / max(1, requests))

        # interleaved best-of-rounds: exact-key hit vs full routed search
        best_hit = best_routed = np.inf
        for _ in range(_ROUNDS):
            best_hit = min(best_hit,
                           timeit_us(lambda: cache.search(one), repeat=9))
            best_routed = min(best_routed,
                              timeit_us(lambda: svc.search(one), repeat=9))
        cache.close()
    speedup = best_routed / best_hit
    rows.append({
        "n": ds.n, "q": requests, "pool": pool_n,
        "hit_rate": round(float(hit_rate), 4),
        "served_p50_us": round(float(np.percentile(lat_us, 50)), 1),
        "served_p90_us": round(float(np.percentile(lat_us, 90)), 1),
        "hit_us": round(best_hit, 1),
        "routed_us": round(best_routed, 1),
        "speedup": round(speedup, 2),
        "hits_exact": st["hits_exact"],
        "hits_semantic": st["hits_semantic"],
        "evictions": (st["evictions_ttl"] + st["evictions_stale"]
                      + st["evictions_capacity"]),
    })
    if verbose:
        r = rows[-1]
        print(f"  n={r['n']} requests={requests} pool={pool_n}: "
              f"hit_rate {r['hit_rate']:.2f} "
              f"(exact {r['hits_exact']}, semantic {r['hits_semantic']}), "
              f"served p50 {r['served_p50_us']:.0f} us, "
              f"hit {best_hit:.0f} us vs routed {best_routed:.0f} us "
              f"= {speedup:.1f}x", flush=True)
    path = emit(rows, "cache")
    return rows, path


if __name__ == "__main__":
    run()
