"""Kernel-level benchmark: the fused mask+distance+top-k hot loop vs the
unfused two-pass baseline (predicate mask materialised, then masked top-k),
swept over corpus size. The Pallas kernel targets TPU (validated in
interpret mode by tests/test_kernels.py); on this CPU host we benchmark the
identical fused jnp formulation that the kernel implements, which is what
XLA:TPU fuses from the same graph."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

from benchmarks.common import emit


@jax.jit
def _two_pass(qv, qb, base, norms, bm):
    mask = ref.predicate_mask_ref(bm, qb, 1)            # materialised [Q, N]
    scores = norms[None, :] - 2.0 * qv @ base.T
    masked = jnp.where(mask, scores, jnp.inf)
    neg, idx = jax.lax.top_k(-masked, 10)
    return jnp.where(jnp.isinf(neg), -1, idx)


@jax.jit
def _fused(qv, qb, base, norms, bm):
    ids, _ = ref.masked_topk_ref(qv, qb, base, norms, bm, pred=1, k=10)
    return ids


def run(verbose=True, sizes=(4096, 16384, 65536)):
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        q, d, w = 64, 64, 4
        qv = jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))
        base = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        norms = jnp.sum(base ** 2, axis=1)
        bm = jnp.asarray(rng.integers(0, 2 ** 20, size=(n, w)).astype(np.uint32))
        qb = jnp.asarray(rng.integers(0, 15, size=(q, w)).astype(np.uint32))
        out = {}
        for name, fn in (("two_pass", _two_pass), ("fused", _fused)):
            fn(qv, qb, base, norms, bm).block_until_ready()
            times = []
            for _ in range(7):
                t0 = time.perf_counter()
                fn(qv, qb, base, norms, bm).block_until_ready()
                times.append(time.perf_counter() - t0)
            # min, not median: the --check gate compares these across
            # runs, and best-of-N is robust to shared-host interference
            out[name] = float(np.min(times) * 1e6)
        rows.append({"n": n, "q": q,
                     "two_pass_us": round(out["two_pass"], 1),
                     "fused_us": round(out["fused"], 1),
                     "speedup": round(out["two_pass"] / out["fused"], 2)})
        if verbose:
            r = rows[-1]
            print(f"  N={n:6d} two-pass={r['two_pass_us']:9.1f}us "
                  f"fused={r['fused_us']:9.1f}us ({r['speedup']}x)",
                  flush=True)
    path = emit(rows, "kernels")
    return rows, path
