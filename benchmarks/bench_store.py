"""Storage-subsystem benchmark: snapshot write bandwidth, cold-open
latency, and WAL replay throughput.

Three gated numbers per (n, rows) row — all wall-clock, lower is
better, so the ``--check`` regression gate compares them uniformly:

* ``snapshot_write_ms`` — `IndexStore.checkpoint()` cost: segment file
  write (vectors + bitmaps + group tables + keys) plus the manifest
  commit. The derived ``write_mb_s`` column reports the implied
  bandwidth over the segment bytes;
* ``cold_open_ms`` — `IndexStore.open()` on a cleanly checkpointed
  store: manifest read, memmap construction, handle build (no WAL
  records to replay — the zero-copy floor of a restart);
* ``wal_replay_ms`` — `IndexStore.open()` when the same ``rows``
  upserts (plus deletes) live only in the WAL; the derived
  ``replay_rows_s`` column is the recovery ingest rate.

Ungated size columns report the segment-v2 bitmap compression:
``bitmap_raw_kb`` (N·W·4 uncompressed) vs ``bitmap_disk_kb``
(word-level RLE on disk) and the resulting ``bitmap_ratio``.
"""

from __future__ import annotations

import os
import shutil
import tempfile

import numpy as np

from repro.ann.live import LiveFilteredIndex
from repro.ann.store import IndexStore
from repro.data.ann_synth import DatasetSpec, synthesize

from benchmarks.common import emit, timeit_best_us

_SPEC = DatasetSpec("bench_store", 8192, 32, 60, 8, 16,
                    1.3, 2.0, 0.5, 0.3, 17)
_SMOKE_SPEC = DatasetSpec("bench_store_smoke", 2048, 32, 60, 8, 16,
                          1.3, 2.0, 0.5, 0.3, 17)


def _segment_bytes(path: str, manifest: dict) -> int:
    seg = os.path.join(path, manifest["segment"])
    return sum(os.path.getsize(os.path.join(seg, f))
               for f in os.listdir(seg)
               if os.path.isfile(os.path.join(seg, f)))


def run(verbose=True, smoke: bool = False, write_rows: int | None = None):
    spec = _SMOKE_SPEC if smoke else _SPEC
    write_rows = write_rows or (512 if smoke else 2048)
    ds = synthesize(spec)
    rng = np.random.default_rng(23)
    src = rng.integers(0, ds.n, write_rows)
    new_vec = (ds.vectors[src]
               + rng.normal(scale=0.01, size=(write_rows, ds.dim))
               .astype(np.float32))
    new_bm = ds.bitmaps[src]

    root = tempfile.mkdtemp(prefix="bench_store_")
    try:
        # --- snapshot write: checkpoint() of the full base ---------------
        path = os.path.join(root, "snap")
        store = IndexStore.create(path, LiveFilteredIndex(ds))
        seg_bytes = _segment_bytes(path, store.manifest)
        seg_dir = os.path.join(path, store.manifest["segment"])
        import json
        with open(os.path.join(seg_dir, "segment.json")) as f:
            seg_meta = json.load(f)
        bm_info = seg_meta["files"]["bitmaps"]
        bitmap_raw = int(np.prod(bm_info["shape"])) * 4
        bitmap_disk = bm_info["bytes"]
        snap_us = timeit_best_us(store.checkpoint, repeat=3)
        write_mb_s = (seg_bytes / (1 << 20)) / (snap_us / 1e6)

        # --- cold open: clean store, nothing to replay -------------------
        store.close()
        open_us = timeit_best_us(
            lambda: IndexStore.open(path).close(), repeat=3)

        # --- WAL replay: the same rows live only in the log --------------
        wal_path = os.path.join(root, "wal")
        wstore = IndexStore.create(wal_path, LiveFilteredIndex(ds))
        for s in range(0, write_rows, 64):
            ids = wstore.index.upsert(new_vec[s: s + 64],
                                      new_bm[s: s + 64])
            if s % 256 == 0:
                wstore.index.delete(ids[:4])
        wstore.close()
        replay_us = timeit_best_us(
            lambda: IndexStore.open(wal_path).close(), repeat=3)
        replay_rows_s = write_rows / max(replay_us - open_us, 1.0) * 1e6
    finally:
        shutil.rmtree(root, ignore_errors=True)

    rows = [{
        "n": ds.n, "rows": write_rows,
        "segment_mb": round(seg_bytes / (1 << 20), 2),
        "snapshot_write_ms": round(snap_us / 1e3, 2),
        "write_mb_s": round(write_mb_s, 1),
        "cold_open_ms": round(open_us / 1e3, 2),
        "wal_replay_ms": round(replay_us / 1e3, 2),
        "replay_rows_s": round(replay_rows_s, 0),
        "bitmap_raw_kb": round(bitmap_raw / 1024, 1),
        "bitmap_disk_kb": round(bitmap_disk / 1024, 1),
        "bitmap_ratio": round(bitmap_disk / max(bitmap_raw, 1), 3),
    }]
    if verbose:
        r = rows[-1]
        print(f"  n={r['n']} rows={r['rows']}: snapshot "
              f"{r['snapshot_write_ms']:.1f} ms ({r['write_mb_s']:.0f} "
              f"MB/s), cold open {r['cold_open_ms']:.1f} ms, WAL replay "
              f"{r['wal_replay_ms']:.1f} ms ({r['replay_rows_s']:.0f} "
              f"rows/s), bitmaps {r['bitmap_raw_kb']:.0f} -> "
              f"{r['bitmap_disk_kb']:.0f} KB "
              f"({r['bitmap_ratio']:.2f}x)", flush=True)
    path = emit(rows, "store")
    return rows, path
