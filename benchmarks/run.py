"""Benchmark orchestrator — one harness per paper table/figure plus the
roofline report. Prints ``name,us_per_call,derived`` CSV summary lines and
writes per-harness CSVs under artifacts/bench/.

  PYTHONPATH=src python -m benchmarks.run [--only table1,pareto,...]
"""

from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,pareto,fig4,table5,table6,"
                         "table7,latency,kernels,roofline")
    args = ap.parse_args()

    from benchmarks import (bench_table1, bench_pareto,
                            bench_feature_ablation, bench_featureset_latency,
                            bench_cls_vs_reg, bench_depth,
                            bench_routing_latency, bench_kernels,
                            bench_roofline)

    harnesses = {
        "table1": ("paper Table 1: best method grid", bench_table1.run),
        "pareto": ("paper Figs 2+5: recall-QPS Pareto", bench_pareto.run),
        "fig4": ("paper Fig 4: feature-count ablation",
                 bench_feature_ablation.run),
        "table5": ("paper Table 5: n=2 vs n=3 latency",
                   bench_featureset_latency.run),
        "table6": ("paper Table 6: classification vs regression",
                   bench_cls_vs_reg.run),
        "table7": ("paper Table 7: MLP depth", bench_depth.run),
        "latency": ("paper §6.3: routing latency breakdown",
                    bench_routing_latency.run),
        "kernels": ("fused mask+distance+topk vs two-pass",
                    bench_kernels.run),
        "roofline": ("roofline terms from the dry-run artifacts",
                     bench_roofline.run),
    }
    sel = args.only.split(",") if args.only else list(harnesses)

    print("name,us_per_call,derived")
    failures = 0
    for key in sel:
        desc, fn = harnesses[key]
        print(f"# == {key}: {desc} ==", flush=True)
        t0 = time.perf_counter()
        try:
            rows, path = fn(verbose=True)
            dt = (time.perf_counter() - t0) * 1e6
            print(f"{key},{dt:.0f},rows={len(rows)};csv={path}", flush=True)
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{key},-1,ERROR={type(e).__name__}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
