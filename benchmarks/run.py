"""Benchmark orchestrator — one harness per paper table/figure plus the
roofline report. Prints ``name,us_per_call,derived`` CSV summary lines and
writes per-harness CSVs under artifacts/bench/.

  PYTHONPATH=src python -m benchmarks.run [--only table1,pareto,...]
  PYTHONPATH=src python -m benchmarks.run --smoke
  PYTHONPATH=src python -m benchmarks.run --check

``--smoke`` runs the kernel, routing-latency, sharded-service, and
live-index harnesses at tiny sizes (synthetic router, no artifact build)
and **appends** a per-PR record (keyed by git SHA) to the
``BENCH_kernels.json`` trajectory at the repo root. ``--check`` compares
the latest recorded run against the median of the last (up to) 3 prior
records and exits 1 if any smoke number regressed by more than 25 %;
every failure line names the regressing metric and the baseline window
(which prior SHAs the median came from). Besides the console lines,
``--check`` writes a machine-readable regression report — every
comparison (trajectory + absolute gates) with its baseline window — to
``artifacts/bench/check_report.json`` and a markdown table twin at
``check_report.md``, so CI can post the verdict without scraping stdout.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import time
import traceback

# allowed slowdown of latest vs previous recorded run before --check fails
CHECK_TOLERANCE = 1.25
# absolute gates (history-independent): fused live search at 50% delta
# fill vs the same corpus compacted into a sealed base (pure liveness
# overhead — both sides serve identical rows), graft-compaction
# wall-clock growth relative to linear-in-base-size, and the telemetry
# sink's hot-path cost (best-of-rounds on vs off, same service)
LIVE_SEALED_MAX = 1.5
COMPACT_SCALING_MAX = 0.9
TELEMETRY_OVERHEAD_MAX = 5.0
# exact-key cache hit must beat the full routed search by at least this
# factor in smoke, or the hit path isn't paying for its bookkeeping
CACHE_SPEEDUP_MIN = 5.0


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bench_path() -> str:
    return os.path.join(_repo_root(), "BENCH_kernels.json")


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"], cwd=_repo_root(),
            capture_output=True, text=True, check=True).stdout.strip()
    except Exception:
        return "unknown"


def _load_runs(path: str) -> list[dict]:
    """Trajectory records, oldest first. Converts the pre-trajectory
    single-record format (top-level "kernels" dict) in place."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict) and "runs" in data:
        return list(data["runs"])
    if isinstance(data, dict) and "kernels" in data:   # legacy single record
        return [{"sha": "pre-trajectory", **data}]
    return []


def _keep_best(old: dict, new: dict) -> dict:
    """Fold a same-SHA re-run into the record, keeping the best (fastest)
    measurement per gated row — re-running --smoke on a shared host
    converges the SHA's record to its noise floor (the cross-invocation
    extension of the best-of-N estimators inside each harness).

    kernels, live_index and telemetry rows take the per-metric min
    (speedup and the gated ratios recomputed from the mins — a ratio
    kept whole from one run would carry that run's slow denominator);
    routing/sharded rows are kept whole from whichever run had the
    faster gated primary, so their component columns stay coherent.
    """
    _TEL_CONFIGS = ("off", "on", "trace", "obslog")
    merged = dict(new)
    for section, key_cols, pick in [
            ("kernels", ("n", "q"), None),
            ("routing_latency", ("dataset", "pred", "q"), "batched_us"),
            ("sharded_service", ("shards", "n", "q"), "batch_us"),
            ("live_index", ("n", "q"), "live_index"),
            ("live_compaction", ("n_base",), "compact_ms"),
            ("store", ("n", "rows"), "cold_open_ms"),
            ("telemetry", ("n", "q"), "telemetry"),
            ("telemetry_adapt", ("n",), "time_to_reroute_ms"),
            ("cache", ("n", "q"), "hit_us")]:
        old_rows = {tuple(r[c] for c in key_cols): r
                    for r in old.get(section, [])}
        out = []
        for row in new.get(section, []):
            prev = old_rows.get(tuple(row[c] for c in key_cols))
            if prev is None:
                out.append(row)
            elif pick is None:                      # kernels: per-metric min
                best = dict(row)
                best["two_pass_us"] = min(row["two_pass_us"],
                                          prev["two_pass_us"])
                best["fused_us"] = min(row["fused_us"], prev["fused_us"])
                best["speedup"] = round(
                    best["two_pass_us"] / best["fused_us"], 2)
                out.append(best)
            elif pick == "live_index":  # per-metric min, ratio recomputed
                best = dict(row)
                for m in ("upsert_us_per_row", "search_compacted_us",
                          "search_live_us"):
                    if m in row and m in prev:
                        best[m] = min(row[m], prev[m])
                if best.get("search_compacted_us"):
                    best["live_sealed_ratio"] = round(
                        best["search_live_us"]
                        / best["search_compacted_us"], 3)
                out.append(best)
            elif pick == "telemetry":   # per-config min, ratios recomputed
                best = dict(row)
                for cfg in _TEL_CONFIGS:
                    m = f"routed_best_us_{cfg}"
                    if m in row and m in prev:
                        best[m] = min(row[m], prev[m])
                off = best.get("routed_best_us_off")
                for cfg, col in (("on", "overhead_pct"),
                                 ("trace", "overhead_trace_pct"),
                                 ("obslog", "overhead_obslog_pct")):
                    m = f"routed_best_us_{cfg}"
                    if off and best.get(m) is not None:
                        best[col] = round(
                            (best[m] / off - 1.0) * 100.0, 2)
                out.append(best)
            else:                                   # whole faster row
                # prev may predate a renamed gate metric: keep the new row
                out.append(row if row[pick] <= prev.get(pick, float("inf"))
                           else prev)
        merged[section] = out
    rl = merged.get("routing_latency", [])
    if rl:
        merged["routing_speedup_median"] = float(
            sorted(r["speedup"] for r in rl)[len(rl) // 2])
    # scaling is defined within one run; recompute it from the merged
    # per-size minima so mixed-run rows stay coherent
    lc = merged.get("live_compaction", [])
    if len(lc) >= 2:
        t0, n0 = lc[0]["compact_ms"], lc[0]["n_base"]
        for row in lc[1:]:
            row["scaling_vs_linear"] = round(
                (row["compact_ms"] / max(t0, 1e-9)) / (row["n_base"] / n0),
                3)
    return merged


def run_smoke() -> None:
    from benchmarks import (bench_cache, bench_kernels, bench_live,
                            bench_routing_latency, bench_sharded,
                            bench_store, bench_telemetry)

    print("# == smoke: kernels (tiny sizes) ==", flush=True)
    rows_k, _ = bench_kernels.run(verbose=True, sizes=(1024, 4096))
    print("# == smoke: routing latency (synthetic router) ==", flush=True)
    rows_l, _ = bench_routing_latency.run(verbose=True, q_batch=256,
                                          smoke=True)
    print("# == smoke: sharded service (1/2 shards, CPU fallback) ==",
          flush=True)
    rows_s, _ = bench_sharded.run(verbose=True, smoke=True)
    print("# == smoke: live index (upserts + search under writes) ==",
          flush=True)
    rows_v, _ = bench_live.run(verbose=True, smoke=True)
    print("# == smoke: graft compaction (2 base sizes) ==", flush=True)
    rows_c, _ = bench_live.run_compaction(verbose=True, smoke=True)
    print("# == smoke: store (snapshot write / cold open / WAL replay) ==",
          flush=True)
    rows_t, _ = bench_store.run(verbose=True, smoke=True)
    print("# == smoke: telemetry overhead (sink on vs off) ==", flush=True)
    rows_m, _ = bench_telemetry.run(verbose=True, smoke=True)
    print("# == smoke: online adaptation (injected drift -> re-route) ==",
          flush=True)
    rows_a, _ = bench_telemetry.run_adaptation(verbose=True, smoke=True)
    print("# == smoke: semantic cache (Zipfian replay, hit vs routed) ==",
          flush=True)
    rows_h, _ = bench_cache.run(verbose=True, smoke=True)
    record = {
        "sha": _git_sha(),
        "date": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "kernels": rows_k,
        "routing_latency": rows_l,
        "sharded_service": rows_s,
        "live_index": rows_v,
        "live_compaction": rows_c,
        "store": rows_t,
        "telemetry": rows_m,
        "telemetry_adapt": rows_a,
        "cache": rows_h,
        "routing_speedup_median": float(
            sorted(r["speedup"] for r in rows_l)[len(rows_l) // 2]),
    }
    path = _bench_path()
    all_runs = _load_runs(path)
    same = [r for r in all_runs if r.get("sha") == record["sha"]]
    if same:                     # re-running a SHA keeps its best numbers
        record = _keep_best(same[-1], record)
    runs = [r for r in all_runs if r.get("sha") != record["sha"]]
    runs.append(record)
    with open(path, "w") as f:
        json.dump({"runs": runs}, f, indent=1)
    print(f"smoke summary -> {path} ({len(runs)} recorded runs)", flush=True)


def _write_check_report(report: list[dict], meta: dict) -> str:
    """Persist the --check verdict machine-readably: a JSON document
    (one entry per comparison, trajectory and absolute gates alike, with
    the baseline window that produced each number) plus a markdown table
    twin for humans/CI comments. Returns the JSON path."""
    from repro.common import artifacts_dir

    out_dir = artifacts_dir("bench")
    jpath = os.path.join(out_dir, "check_report.json")
    with open(jpath, "w") as f:
        json.dump({**meta, "comparisons": report}, f, indent=1)
    lines = [
        "# Bench regression check",
        "",
        f"- run: `{meta['sha']}` ({meta['date']})",
        f"- baseline: median of last ≤3 prior records; "
        f"tolerance {meta['tolerance']}x",
        f"- verdict: **{'FAIL' if meta['failures'] else 'PASS'}** "
        f"({meta['failures']} regression(s) / "
        f"{len(report)} comparison(s))",
        "",
        "| section | key | metric | baseline | current | ratio | gate "
        "| status | window |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in report:
        lines.append(
            "| {section} | {key} | {metric} | {baseline} | {current} "
            "| {ratio} | {gate} | {status} | {window} |".format(
                section=c["section"],
                key=",".join(str(k) for k in c["key"]),
                metric=c["metric"],
                baseline="—" if c["baseline"] is None else c["baseline"],
                current=c["current"],
                ratio="—" if c["ratio"] is None else f"{c['ratio']:.2f}x",
                gate=c["gate"], status=c["status"],
                window=" ".join(c["window"]) or "—"))
    with open(os.path.join(out_dir, "check_report.md"), "w") as f:
        f.write("\n".join(lines) + "\n")
    return jpath


def run_check() -> None:
    """Fail (exit 1) if the latest recorded smoke run regressed >25% vs
    the trajectory baseline on any gated number.

    The baseline per metric is the **median over the last (up to) 3
    prior records** carrying it, not the single previous record: one
    lucky-fast (or polluted) historical sample on a shared host would
    otherwise gate every later run against an unrepresentative number.

    Every comparison is also appended to the machine-readable report
    written by `_write_check_report` (JSON + markdown twin under
    artifacts/bench/), pass or fail.
    """
    import statistics

    runs = _load_runs(_bench_path())
    if len(runs) < 2:
        print(f"check: only {len(runs)} recorded run(s) — nothing to "
              f"compare, passing", flush=True)
        return
    prior, last = runs[:-1], runs[-1]
    report: list[dict] = []
    print(f"check: {last.get('sha')} vs median of last "
          f"{min(3, len(prior))} prior record(s) "
          f"(tolerance {CHECK_TOLERANCE}x)")
    comparisons = [
        ("kernels", ("n", "q"), ("fused_us", "two_pass_us")),
        ("routing_latency", ("dataset", "pred", "q"),
         ("batched_us", "per_query_us")),
        ("sharded_service", ("shards", "n", "q"), ("batch_us",)),
        ("live_index", ("n", "q"),
         ("upsert_us_per_row", "search_compacted_us", "search_live_us")),
        ("live_compaction", ("n_base",), ("compact_ms",)),
        ("store", ("n", "rows"),
         ("snapshot_write_ms", "cold_open_ms", "wal_replay_ms")),
        ("telemetry", ("n", "q"),
         ("routed_best_us_off", "routed_best_us_on",
          "routed_best_us_trace", "routed_best_us_obslog")),
        ("cache", ("n", "q"), ("hit_us", "served_p50_us")),
    ]
    failures: list[str] = []
    for section, key_cols, metrics in comparisons:
        history: dict = {}   # (key, metric) -> [(sha, val), oldest..]
        for r in prior:
            for row in r.get(section, []):
                key = tuple(row[c] for c in key_cols)
                for metric in metrics:
                    if metric in row:
                        history.setdefault((key, metric), []).append(
                            (r.get("sha", "?"), row[metric]))
        for row in last.get(section, []):
            key = tuple(row[c] for c in key_cols)
            for metric in metrics:
                window = history.get((key, metric))
                if metric not in row or not window:
                    continue
                window = window[-3:]
                base = statistics.median(v for _, v in window)
                ratio = row[metric] / max(base, 1e-9)
                flag = "REGRESSION" if ratio > CHECK_TOLERANCE else "ok"
                report.append({
                    "kind": "trajectory", "section": section,
                    "key": list(key), "metric": metric,
                    "baseline": base, "current": row[metric],
                    "ratio": round(ratio, 3),
                    "gate": f"<= {CHECK_TOLERANCE}x", "status": flag,
                    "window": [sha for sha, _ in window]})
                if ratio > CHECK_TOLERANCE:
                    failures.append(
                        f"{section}{list(key)} {metric}: {base} -> "
                        f"{row[metric]} ({ratio:.2f}x > "
                        f"{CHECK_TOLERANCE}x) vs median of "
                        f"{len(window)} prior record(s) "
                        f"[{', '.join(sha for sha, _ in window)}]")
                print(f"  {section}{list(key)} {metric}: "
                      f"{base} -> {row[metric]} "
                      f"({ratio:.2f}x) {flag}", flush=True)
    # absolute acceptance gates, independent of trajectory history: the
    # fused live read path must hold <=1.5x sealed at 50% delta fill,
    # the telemetry sink must cost <=5% on the routed hot path, and
    # graft compaction must scale sublinearly in base size
    def absolute_gate(section: str, key: list, metric: str, value,
                      limit: float, *, below: bool = False) -> None:
        """One history-independent gate: fail when `value` exceeds
        `limit` (or falls below it with `below=True`)."""
        bad = (value < limit) if below else (value > limit)
        gate = f"{'>=' if below else '<='} {limit}"
        report.append({
            "kind": "absolute", "section": section, "key": key,
            "metric": metric, "baseline": None, "current": value,
            "ratio": None, "gate": gate,
            "status": "REGRESSION" if bad else "ok", "window": []})
        if bad:
            failures.append(
                f"{section}{key} {metric}: {value} "
                f"{'<' if below else '>'} {limit} (absolute gate)")
        print(f"  {section}{key} {metric}: {value} (gate {gate}) "
              f"{'REGRESSION' if bad else 'ok'}", flush=True)

    for row in last.get("live_index", []):
        if row.get("live_sealed_ratio") is not None:
            absolute_gate("live_index", [row.get("n"), row.get("q")],
                          "live_sealed_ratio", row["live_sealed_ratio"],
                          LIVE_SEALED_MAX)
    for row in last.get("telemetry", []):
        key = [row.get("n"), row.get("q")]
        if row.get("overhead_pct") is not None:
            absolute_gate("telemetry", key, "overhead_pct",
                          row["overhead_pct"], TELEMETRY_OVERHEAD_MAX)
        # combined sink+tracer overhead shares the same 5% budget: the
        # span layer must stay invisible on the routed hot path
        if row.get("overhead_trace_pct") is not None:
            absolute_gate("telemetry", key, "overhead_trace_pct",
                          row["overhead_trace_pct"],
                          TELEMETRY_OVERHEAD_MAX)
        # the full observability stack (sink + tracer + wide-event log)
        # shares the same absolute budget: emit is a ring-slot claim,
        # serialisation and I/O belong to the writer thread
        if row.get("overhead_obslog_pct") is not None:
            absolute_gate("telemetry", key, "overhead_obslog_pct",
                          row["overhead_obslog_pct"],
                          TELEMETRY_OVERHEAD_MAX)
    for row in last.get("cache", []):
        if row.get("speedup") is not None:
            absolute_gate("cache", [row.get("n"), row.get("q")],
                          "speedup", row["speedup"], CACHE_SPEEDUP_MIN,
                          below=True)
    comp = [r for r in last.get("live_compaction", [])
            if "scaling_vs_linear" in r]
    for row in comp[1:]:            # first row is its own baseline (1.0)
        absolute_gate("live_compaction", [row["n_base"]],
                      "scaling_vs_linear", row["scaling_vs_linear"],
                      COMPACT_SCALING_MAX)
    jpath = _write_check_report(report, {
        "sha": last.get("sha", "?"), "date": last.get("date", "?"),
        "tolerance": CHECK_TOLERANCE, "failures": len(failures)})
    print(f"check report -> {jpath} (+ .md)", flush=True)
    if failures:
        print(f"check: {len(failures)} regression(s) beyond "
              f"{CHECK_TOLERANCE}x:", flush=True)
        for f in failures:
            print(f"  REGRESSION {f}", flush=True)
        raise SystemExit(1)
    print("check: no regressions beyond tolerance", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,pareto,fig4,table5,table6,"
                         "table7,latency,kernels,sharded,live,store,"
                         "telemetry,cache,roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-size kernels+latency run, appends a per-PR "
                         "record to BENCH_kernels.json at the repo root")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if the latest recorded smoke run regressed "
                         ">25%% vs the median of the last <=3 prior records")
    args = ap.parse_args()

    # --smoke --check composes: record this SHA, then gate against the
    # previous record
    if args.smoke:
        run_smoke()
    if args.check:
        run_check()
    if args.smoke or args.check:
        return

    from benchmarks import (bench_table1, bench_pareto,
                            bench_feature_ablation, bench_featureset_latency,
                            bench_cache, bench_cls_vs_reg, bench_depth,
                            bench_routing_latency, bench_kernels,
                            bench_live, bench_roofline, bench_sharded,
                            bench_store, bench_telemetry)

    harnesses = {
        "table1": ("paper Table 1: best method grid", bench_table1.run),
        "pareto": ("paper Figs 2+5: recall-QPS Pareto", bench_pareto.run),
        "fig4": ("paper Fig 4: feature-count ablation",
                 bench_feature_ablation.run),
        "table5": ("paper Table 5: n=2 vs n=3 latency",
                   bench_featureset_latency.run),
        "table6": ("paper Table 6: classification vs regression",
                   bench_cls_vs_reg.run),
        "table7": ("paper Table 7: MLP depth", bench_depth.run),
        "latency": ("paper §6.3: routing latency breakdown",
                    bench_routing_latency.run),
        "kernels": ("fused mask+distance+topk vs two-pass",
                    bench_kernels.run),
        "sharded": ("sharded service vs single-index dispatch",
                    bench_sharded.run),
        "live": ("live index: upsert throughput + search under writes",
                 bench_live.run),
        "store": ("storage: snapshot write / cold open / WAL replay",
                  bench_store.run),
        "telemetry": ("telemetry sink overhead on the routed hot path",
                      bench_telemetry.run),
        "cache": ("semantic cache: Zipfian hit-rate + hit vs routed",
                  bench_cache.run),
        "roofline": ("roofline terms from the dry-run artifacts",
                     bench_roofline.run),
    }
    sel = args.only.split(",") if args.only else list(harnesses)

    print("name,us_per_call,derived")
    failures = 0
    for key in sel:
        desc, fn = harnesses[key]
        print(f"# == {key}: {desc} ==", flush=True)
        t0 = time.perf_counter()
        try:
            rows, path = fn(verbose=True)
            dt = (time.perf_counter() - t0) * 1e6
            print(f"{key},{dt:.0f},rows={len(rows)};csv={path}", flush=True)
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{key},-1,ERROR={type(e).__name__}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
