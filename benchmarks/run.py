"""Benchmark orchestrator — one harness per paper table/figure plus the
roofline report. Prints ``name,us_per_call,derived`` CSV summary lines and
writes per-harness CSVs under artifacts/bench/.

  PYTHONPATH=src python -m benchmarks.run [--only table1,pareto,...]
  PYTHONPATH=src python -m benchmarks.run --smoke

``--smoke`` runs the kernel and routing-latency harnesses at tiny sizes
(synthetic router, no artifact build) and writes a ``BENCH_kernels.json``
summary at the repo root so successive PRs have a perf trajectory to
compare against.
"""

from __future__ import annotations

import argparse
import json
import os
import time
import traceback


def run_smoke() -> None:
    from benchmarks import bench_kernels, bench_routing_latency

    print("# == smoke: kernels (tiny sizes) ==", flush=True)
    rows_k, _ = bench_kernels.run(verbose=True, sizes=(1024, 4096))
    print("# == smoke: routing latency (synthetic router) ==", flush=True)
    rows_l, _ = bench_routing_latency.run(verbose=True, q_batch=256,
                                          smoke=True)
    summary = {
        "kernels": rows_k,
        "routing_latency": rows_l,
        "routing_speedup_median": float(
            sorted(r["speedup"] for r in rows_l)[len(rows_l) // 2]),
    }
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_kernels.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=1)
    print(f"smoke summary -> {path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,pareto,fig4,table5,table6,"
                         "table7,latency,kernels,roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-size kernels+latency run, writes "
                         "BENCH_kernels.json at the repo root")
    args = ap.parse_args()

    if args.smoke:
        run_smoke()
        return

    from benchmarks import (bench_table1, bench_pareto,
                            bench_feature_ablation, bench_featureset_latency,
                            bench_cls_vs_reg, bench_depth,
                            bench_routing_latency, bench_kernels,
                            bench_roofline)

    harnesses = {
        "table1": ("paper Table 1: best method grid", bench_table1.run),
        "pareto": ("paper Figs 2+5: recall-QPS Pareto", bench_pareto.run),
        "fig4": ("paper Fig 4: feature-count ablation",
                 bench_feature_ablation.run),
        "table5": ("paper Table 5: n=2 vs n=3 latency",
                   bench_featureset_latency.run),
        "table6": ("paper Table 6: classification vs regression",
                   bench_cls_vs_reg.run),
        "table7": ("paper Table 7: MLP depth", bench_depth.run),
        "latency": ("paper §6.3: routing latency breakdown",
                    bench_routing_latency.run),
        "kernels": ("fused mask+distance+topk vs two-pass",
                    bench_kernels.run),
        "roofline": ("roofline terms from the dry-run artifacts",
                     bench_roofline.run),
    }
    sel = args.only.split(",") if args.only else list(harnesses)

    print("name,us_per_call,derived")
    failures = 0
    for key in sel:
        desc, fn = harnesses[key]
        print(f"# == {key}: {desc} ==", flush=True)
        t0 = time.perf_counter()
        try:
            rows, path = fn(verbose=True)
            dt = (time.perf_counter() - t0) * 1e6
            print(f"{key},{dt:.0f},rows={len(rows)};csv={path}", flush=True)
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{key},-1,ERROR={type(e).__name__}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
