"""Paper Figs 2 & 5: recall-QPS Pareto per (validation dataset × predicate) —
every baseline (method, ps) point from table B, the RuleRouter's pick, the
Oracle bound, and the ML Router curve traced by sweeping T with REAL
execution (search wall-clock + routing overhead included, as in §6.3)."""

from __future__ import annotations

import time

import numpy as np

from repro.ann.dataset import recall_at_k
from repro.ann.index import QueryBatch, default_index
from repro.ann.predicates import Predicate
from repro.ann.service import RouterService
from repro.core import features as F
from repro.core.oracle import oracle_recall, oracle_choice
from repro.core.rule_router import RuleRouter
from repro.core.training import METHOD_ORDER
from repro.data.ann_synth import get_dataset, make_queries

from benchmarks.common import emit, load_artifacts

T_SWEEP = (0.5, 0.8, 0.9, 0.95, 0.99)


def run(verbose=True, n_queries: int = 200):
    coll_train, coll_val, router = load_artifacts(verbose=False)
    rows = []
    rule = RuleRouter()
    for (ds_name, pt), cell in sorted(coll_val.cells.items()):
        ds = get_dataset(ds_name)
        pred = Predicate(pt)
        # --- baselines: every (method, ps) point from B ---
        for m, ps_id, rec, qps in cell.sweep:
            rows.append({"dataset": ds_name, "pred": pred.name,
                         "series": m, "point": ps_id,
                         "recall": round(rec, 4), "qps": round(qps, 1)})
        # --- RuleRouter pick ---
        dsf = F.dataset_features(ds)
        pick = rule.route(pred, dsf.values["lid_mean"],
                          dsf.values["label_cardinality"])
        best_of_pick = max((s for s in cell.sweep if s[0] == pick),
                           key=lambda s: (round(s[2], 3), s[3]))
        rows.append({"dataset": ds_name, "pred": pred.name,
                     "series": "RuleRouter", "point": pick,
                     "recall": round(best_of_pick[2], 4),
                     "qps": round(best_of_pick[3], 1)})
        # --- Oracle (recall bound; QPS estimated from chosen methods) ---
        orc = oracle_recall(coll_val, ds_name, pt)
        choice = oracle_choice(coll_val, ds_name, pt)
        o_time = 0.0
        for ci in choice:
            m = METHOD_ORDER[ci]
            best = max((s for s in cell.sweep if s[0] == m),
                       key=lambda s: (round(s[2], 3), s[3]))
            o_time += 1.0 / max(best[3], 1e-9)
        rows.append({"dataset": ds_name, "pred": pred.name,
                     "series": "Oracle", "point": "",
                     "recall": round(float(orc.mean()), 4),
                     "qps": round(len(choice) / o_time, 1)})
        # --- ML Router: REAL execution across the T sweep ---
        svc = RouterService(default_index(ds), router)
        qs = make_queries(ds, pred, n_queries, seed=1)   # same seed family
        batch = QueryBatch(qs.vectors, qs.bitmaps, pred, k=10)
        for t_thresh in T_SWEEP:
            t0 = time.perf_counter()
            res = svc.search(batch, t=t_thresh)
            dt = time.perf_counter() - t0
            rec = recall_at_k(res.ids, qs.ground_truth).mean()
            rows.append({"dataset": ds_name, "pred": pred.name,
                         "series": "MLRouter", "point": f"T={t_thresh}",
                         "recall": round(float(rec), 4),
                         "qps": round(qs.q / dt, 1)})
            if verbose:
                print(f"  {ds_name:14s} {pred.name:8s} T={t_thresh:4} "
                      f"recall={rec:.3f} qps={qs.q/dt:8.1f}", flush=True)
    path = emit(rows, "pareto")
    return rows, path
