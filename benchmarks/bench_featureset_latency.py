"""Paper Table 5 (§6.2a): end-to-end per-query latency under the n=2 vs n=3
candidate feature sets on the two real-text validation datasets — the
tie-break that selects the 3-feature minimal set (lid_mean steers the
router away from latency-heavy methods)."""

from __future__ import annotations

import time

import numpy as np

from repro.ann.index import QueryBatch, default_index
from repro.ann.predicates import Predicate
from repro.ann.service import RouterService
from repro.core import features as F
from repro.core import training as T
from repro.core.router import MLRouter
from repro.data.ann_synth import get_dataset, make_queries

from benchmarks.common import emit, load_artifacts

FEATURE_SETS = {
    2: ["selectivity", "pred"],
    3: F.MINIMAL_FEATURES,            # selectivity, lid_mean, pred
}


def run(verbose=True, n_queries: int = 150):
    coll_train, coll_val, _ = load_artifacts(verbose=False)
    rows = []
    routers = {}
    for n, feats in FEATURE_SETS.items():
        models, scaler = T.train_models(coll_train, feats, seed=0, epochs=120)
        routers[n] = MLRouter(feature_names=feats, methods=T.METHOD_ORDER,
                              models=models, scaler=scaler,
                              table=coll_train.table)
    for ds_name in ("dbpedia560k", "yahoo800k"):
        ds = get_dataset(ds_name)
        fx = default_index(ds)
        lat = {}
        for n, router in routers.items():
            svc = RouterService(fx, router, t=0.9)
            total = 0.0
            for pred in (Predicate.AND, Predicate.OR):
                qs = make_queries(ds, pred, n_queries, seed=11,
                                  with_ground_truth=False)
                # warm the jits for whatever this router dispatches to
                svc.search(QueryBatch(qs.vectors[:8], qs.bitmaps[:8],
                                      pred, k=10))
                batch = QueryBatch(qs.vectors, qs.bitmaps, pred, k=10)
                t0 = time.perf_counter()
                svc.search(batch)
                total += time.perf_counter() - t0
            lat[n] = total / (2 * n_queries) * 1e6
        rows.append({"dataset": ds_name,
                     "n2_latency_us": round(lat[2], 1),
                     "n3_latency_us": round(lat[3], 1),
                     "speedup": round(lat[2] / lat[3], 2)})
        if verbose:
            r = rows[-1]
            print(f"  {ds_name:14s} n=2 {r['n2_latency_us']:9.1f}us  "
                  f"n=3 {r['n3_latency_us']:9.1f}us  ({r['speedup']}x)",
                  flush=True)
    path = emit(rows, "table5_featureset_latency")
    return rows, path
