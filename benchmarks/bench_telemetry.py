"""Telemetry-overhead + online-adaptation benchmark.

``run`` measures the routed-search hot path with the `TelemetrySink`
attached vs detached on the *same* service (identical compiled kernels
and index state — only the sink toggles). Rounds interleave on/off and
the gated ratio compares best-of-rounds to best-of-rounds, so a noisy
neighbour inflating one round can't fake an overhead regression:

* ``routed_best_us_off`` / ``routed_best_us_on`` — best (min) routed
  batch latency across interleaved rounds. The min, not the median:
  an absolute few-percent gate needs the intrinsic-cost estimator, and
  on a shared host the median of small samples swings more than the
  gate width (measured ±4 % run-to-run idle), which would make the
  gate fire on scheduler noise.
* ``overhead_pct`` — (on/off − 1)·100, gated **absolutely** at 5 % by
  ``--check`` (TELEMETRY_OVERHEAD_MAX): recording events, folding
  counters, and reservoir admission must stay effectively free.
* ``routed_best_us_trace`` / ``overhead_trace_pct`` — a third
  interleaved config with sink **and** a production-shaped `Tracer`
  (tail-based: `slow_ms=50`, head sample 5 %) attached; the combined
  sink+trace overhead is gated at the same absolute 5 %. This is the
  ISSUE's ≤5 % tracing budget: every request builds its span tree, the
  sampler just decides retention, so the gate covers the full cost.
* ``routed_best_us_obslog`` / ``overhead_obslog_pct`` — a fourth
  interleaved config adding the `WideEventLog` on top of sink+trace
  (one structured JSONL event per query into the lock-free ring; the
  background writer drains to a temp file). The *full* observability
  stack — sink + trace + wide events — is gated at the same absolute
  5 %: emit is a ring-slot claim plus dict build, serialisation and
  I/O live on the writer thread.

``run_adaptation`` measures the control loop end-to-end: the routed
method gets an injected recall regression (`DegradedMethod` truncates
its results), sampled audits fold exact recall into the EWMA table,
and the run records how many audit rounds (`reroute_rounds`) and how
much wall-clock (`time_to_reroute_ms`) until the router's decisions
shift off the degraded method, plus `audit_qps` (oracle replays per
second). These are control-loop wall-clock numbers — recorded for
trend-watching, not history-gated.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.ann.index import FilteredIndex, QueryBatch
from repro.ann.predicates import Predicate
from repro.ann.registry import candidate_methods
from repro.ann.service import RouterService
from repro.ann.telemetry import (DegradedMethod, OnlineRouterAdapter,
                                 TelemetrySink, constant_router)
from repro.ann.trace import Tracer
from repro.core import features as F
from repro.core.table import BenchmarkTable
from repro.data.ann_synth import DatasetSpec, make_queries, synthesize

from benchmarks.common import emit, timeit_best_us

_SPEC = DatasetSpec("bench_tel", 8192, 32, 60, 8, 16,
                    1.3, 2.0, 0.5, 0.3, 17)
_SMOKE_SPEC = DatasetSpec("bench_tel_smoke", 2048, 32, 60, 8, 16,
                          1.3, 2.0, 0.5, 0.3, 17)
# enough interleaved rounds x repeats that every config's min reaches
# its floor in one invocation: the gated numbers are ratios of mins,
# and an under-sampled config inflates its ratio by pure scheduler
# noise (the off config has 1/4 fewer moving parts and bottoms out
# first, so under-sampling biases every overhead gate upward)
_ROUNDS = 7
_REPEAT = 15


def _dense_table(ds_name: str, methods: list, seed: int = 0):
    """Dense synthetic table over the real method registry (the
    bench_routing_latency idiom): recall in [0.91, 1.0] so every
    (method, ps) passes t=0.9 and routing exercises the full
    Algorithm 2 table path."""
    rng = np.random.default_rng(seed)
    cand = candidate_methods()
    table = BenchmarkTable.new()
    for m in methods:
        for s in cand[m].param_settings():
            for pt in range(3):
                table.add(ds_name, pt, m, s.ps_id,
                          rng.uniform(0.91, 1.0), rng.uniform(100, 2000))
    return table


def run(verbose=True, smoke: bool = False, q: int | None = None):
    spec, q = (_SMOKE_SPEC, q or 64) if smoke else (_SPEC, q or 128)
    ds = synthesize(spec)
    methods = ["labelnav", "postfilter", "sieve", "ivf_gamma", "fvamana"]
    table = _dense_table(ds.name, methods)
    router = constant_router(F.MINIMAL_FEATURES, methods, table)
    qs = make_queries(ds, Predicate.AND, q, seed=5)
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    rows = []
    from repro.ann.obslog import WideEventLog
    tmp = tempfile.mkdtemp(prefix="bench_obslog_")
    obslog = WideEventLog(os.path.join(tmp, "events.jsonl"),
                          capacity=8192)
    with FilteredIndex(ds) as fx:
        svc = RouterService(fx, router, t=0.9)
        sink = TelemetrySink(capacity=4096, reservoir=128, seed=7)
        # production-shaped tracer: tail-keep slow traces, 5% head sample
        tracer = Tracer(slow_ms=50.0, sample=0.05, flight_capacity=16,
                        seed=11)
        svc.search(batch)                       # warm-up + compile
        svc.telemetry = sink
        svc.tracer = tracer
        svc.obslog = obslog
        svc.search(batch)                       # warm sink+trace+log paths
        svc.obslog = None
        best_off = best_on = best_tr = best_ol = np.inf
        for _ in range(_ROUNDS):                # interleave the 4 configs
            svc.telemetry, svc.tracer = None, None
            best_off = min(best_off, timeit_best_us(
                lambda: svc.search(batch), repeat=_REPEAT))
            svc.telemetry, svc.tracer = sink, None
            best_on = min(best_on, timeit_best_us(
                lambda: svc.search(batch), repeat=_REPEAT))
            svc.telemetry, svc.tracer = sink, tracer
            best_tr = min(best_tr, timeit_best_us(
                lambda: svc.search(batch), repeat=_REPEAT))
            svc.obslog = obslog
            best_ol = min(best_ol, timeit_best_us(
                lambda: svc.search(batch), repeat=_REPEAT))
            svc.obslog = None
        events = sink.stats()["queries"]
        traces = tracer.stats()["traces"]
        wide = obslog.stats()
    obslog.close()
    overhead = (best_on / best_off - 1.0) * 100.0
    overhead_tr = (best_tr / best_off - 1.0) * 100.0
    overhead_ol = (best_ol / best_off - 1.0) * 100.0
    rows.append({"n": ds.n, "q": q,
                 "routed_best_us_off": round(best_off, 1),
                 "routed_best_us_on": round(best_on, 1),
                 "routed_best_us_trace": round(best_tr, 1),
                 "routed_best_us_obslog": round(best_ol, 1),
                 "overhead_pct": round(overhead, 2),
                 "overhead_trace_pct": round(overhead_tr, 2),
                 "overhead_obslog_pct": round(overhead_ol, 2),
                 "events": int(events), "traces": int(traces),
                 "wide_events": int(wide["emitted"]),
                 "wide_dropped": int(wide["dropped"])})
    if verbose:
        r = rows[-1]
        print(f"  n={r['n']} q={q}: routed off {best_off:.0f} us -> on "
              f"{best_on:.0f} us = {overhead:+.2f}% overhead; +trace "
              f"{best_tr:.0f} us = {overhead_tr:+.2f}%; +obslog "
              f"{best_ol:.0f} us = {overhead_ol:+.2f}% "
              f"({r['events']} events, {r['traces']} traces, "
              f"{r['wide_events']} wide events, "
              f"{r['wide_dropped']} dropped)", flush=True)
    path = emit(rows, "telemetry")
    return rows, path


def run_adaptation(verbose=True, smoke: bool = False):
    """Injected drift -> measured time until the router re-routes."""
    spec = _SMOKE_SPEC if smoke else _SPEC
    ds = synthesize(spec)
    methods = ["ivf_gamma", "postfilter"]
    cand = candidate_methods()
    table = BenchmarkTable.new()
    for pt in range(3):
        # ivf_gamma passes t with the best QPS -> routed everywhere;
        # postfilter is the passing alternative the EWMA shift exposes
        for s in cand["ivf_gamma"].param_settings():
            table.add(ds.name, pt, "ivf_gamma", s.ps_id, 0.97, 5000.0)
        for s in cand["postfilter"].param_settings():
            table.add(ds.name, pt, "postfilter", s.ps_id, 0.95, 500.0)
    router = constant_router(F.MINIMAL_FEATURES, methods, table)
    qs = make_queries(ds, Predicate.AND, 32, seed=9)
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    rows = []
    with FilteredIndex(ds) as fx:
        serving = dict(candidate_methods())
        serving["ivf_gamma"] = DegradedMethod(serving["ivf_gamma"], keep=2)
        sink = TelemetrySink(capacity=2048, reservoir=96, seed=3)
        svc = RouterService(fx, router, t=0.9, methods=serving,
                            telemetry=sink)
        # EWMA alpha 0.5 + drift threshold above the retrain trigger:
        # this harness times the *table-driven* re-route, not retrain
        adapter = OnlineRouterAdapter(svc, sink, alpha=0.5,
                                      drift_threshold=2.0, seed=1)
        svc.search(batch)                        # warm-up + compile
        frac0 = np.mean([d.method == "ivf_gamma"
                         for d in svc.route(batch)])
        t0 = time.perf_counter()
        rounds = 0
        audit_s = 0.0
        audited = 0
        while rounds < 20:
            svc.search(batch)
            ta = time.perf_counter()
            rep = adapter.step()
            audit_s += time.perf_counter() - ta
            audited += rep["samples"]
            rounds += 1
            frac = np.mean([d.method == "ivf_gamma"
                            for d in svc.route(batch)])
            if frac == 0.0:
                break
        reroute_ms = (time.perf_counter() - t0) * 1e3
        audit_qps = audited / max(audit_s, 1e-9)
    rows.append({"n": ds.n,
                 "routed_before": round(float(frac0), 3),
                 "routed_after": round(float(frac), 3),
                 "reroute_rounds": rounds,
                 "time_to_reroute_ms": round(reroute_ms, 1),
                 "audit_qps": round(audit_qps, 1)})
    if verbose:
        r = rows[-1]
        print(f"  n={r['n']}: degraded-method share "
              f"{r['routed_before']:.2f} -> {r['routed_after']:.2f} in "
              f"{r['reroute_rounds']} audit round(s), "
              f"{r['time_to_reroute_ms']:.0f} ms "
              f"(audit {r['audit_qps']:.0f} q/s)", flush=True)
    path = emit(rows, "telemetry_adapt")
    return rows, path
