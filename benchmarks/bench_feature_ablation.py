"""Paper Fig. 4 (§6.2a): nested feature ablation — RandomForest importance
ranking on the training set, nested subsets of size n (each + predicate
type), MLP-Reg retrained per (n, seed), validation recall mean ± std."""

from __future__ import annotations

import numpy as np

from repro.core import features as F
from repro.core import training as T
from repro.core.forest import RandomForest

from benchmarks.common import emit, load_artifacts

N_SWEEP = (1, 2, 3, 5, 8, 12, 16, 21)
SEEDS = (0, 1, 2)   # paper uses 5; 3 keeps the 1-core budget (noted)


def routed_recall(coll_val, router_models, scaler, feature_names, t=0.9,
                  table=None):
    from repro.core.router import MLRouter

    router = MLRouter(feature_names=feature_names, methods=T.METHOD_ORDER,
                      models=router_models, scaler=scaler, table=table)
    recs = []
    for (ds, pt), cell in coll_val.cells.items():
        x, _, _ = T.assemble_xy(
            T.Collection(cells={(ds, pt): cell}, table=table), feature_names)
        r_hat = router.predict_recalls_from_features(x)
        dec = router.route_from_predictions(r_hat, ds, pt, t)
        recs.extend(cell.recall[m][i] for i, (m, _) in enumerate(dec))
    return float(np.mean(recs))


def importance_ranking(coll_train):
    x, y, _ = T.assemble_xy(coll_train, F.NUMERIC_FEATURES)
    rf = RandomForest(n_trees=12, max_depth=8, seed=0).fit(
        x, y.mean(axis=1))       # importance for predicting method recall
    order = np.argsort(-rf.feature_importances_)
    return [F.NUMERIC_FEATURES[i] for i in order], rf.feature_importances_


def run(verbose=True):
    coll_train, coll_val, base_router = load_artifacts(verbose=False)
    ranked, imp = importance_ranking(coll_train)
    if verbose:
        print("  RF importance ranking:",
              ", ".join(f"{n}" for n in ranked[:8]), "...")
    rows = []
    for n in N_SWEEP:
        feats = ranked[:n] + ["pred"]
        vals = []
        for seed in SEEDS:
            models, scaler = T.train_models(coll_train, feats, seed=seed,
                                            epochs=80)
            vals.append(routed_recall(coll_val, models, scaler, feats,
                                      table=coll_train.table))
        rows.append({"n_features": n,
                     "recall_mean": round(float(np.mean(vals)), 4),
                     "recall_std": round(float(np.std(vals)), 4),
                     "features": "|".join(ranked[:n])})
        if verbose:
            print(f"  n={n:2d} recall={np.mean(vals):.4f} "
                  f"±{np.std(vals):.4f}", flush=True)
    path = emit(rows, "fig4_feature_ablation")
    return rows, path
