"""Paper Table 1: best-performing method per (training dataset × predicate
type), alongside LID_mean and card(V) — the observations RuleRouter encodes."""

from __future__ import annotations

from repro.ann.predicates import Predicate
from repro.ann.methods import PAPER_NAMES
from repro.core import features as F
from repro.data.ann_synth import get_dataset

from benchmarks.common import emit, load_artifacts


def run(verbose=True):
    coll_train, _, _ = load_artifacts(verbose=False)
    rows = []
    for ds_name in sorted({k[0] for k in coll_train.cells}):
        ds = get_dataset(ds_name)
        dsf = F.dataset_features(ds)
        row = {"dataset": ds_name,
               "lid_mean": round(dsf.values["lid_mean"], 1),
               "card": int(dsf.values["label_cardinality"])}
        for pred in Predicate:
            cell = coll_train.cells[(ds_name, int(pred))]
            # winner = max mean recall, tie-break QPS (from the sweep)
            best = max(cell.sweep,
                       key=lambda s: (round(s[2], 3), s[3]))
            row[pred.name] = PAPER_NAMES.get(best[0], best[0])
        rows.append(row)
    path = emit(rows, "table1_best_method")
    if verbose:
        for r in rows:
            print(f"  {r['dataset']:14s} LID={r['lid_mean']:6.1f} "
                  f"card={r['card']:6d} EQ={r['EQUALITY']:14s} "
                  f"AND={r['AND']:14s} OR={r['OR']}")
    return rows, path
