"""Paper §6.3 routing-latency breakdown, before/after the batched rewrite.

Measures, per predicate type at a configurable batch size:
  * the seed per-query pipeline (Q Python iterations, each doing two
    host-side selectivity scans + numpy MLP forwards + a per-query
    Algorithm 2 pass) — preserved here as the latency reference;
  * the batched pipeline (`MLRouter.route`): one vectorised feature pass,
    one stacked-MLP forward, array-op Algorithm 2;
and reports the component breakdown of the batched path, the end-to-end
speedup, and the paper's §6.3 routing-to-search latency ratio (batched
per-query routing cost over the median per-query search latency from the
offline table B). `smoke=True` swaps the artifact-built router for a
synthetic one on a small dataset so the harness runs in seconds."""

from __future__ import annotations

import time

import numpy as np

from repro.ann.index import QueryBatch, default_index
from repro.ann.predicates import PREDICATES, Predicate
from repro.ann.service import RouterService
from repro.core import features as F
from repro.core import mlp as mlp_mod
from repro.core.router import MLRouter
from repro.core.table import BenchmarkTable
from repro.data.ann_synth import DatasetSpec, get_dataset, make_queries, synthesize

from benchmarks.common import emit, load_artifacts

_SMOKE_SPEC = DatasetSpec("smoke_rt", 4000, 32, 60, 8, 16, 1.3, 2.0, 0.5, 0.3, 42)
_SMOKE_METHODS = ["labelnav", "postfilter", "sieve", "ivf_gamma", "fvamana"]


def _smoke_setup():
    """Small dataset + randomly initialised router (no artifact build)."""
    import jax

    ds = synthesize(_SMOKE_SPEC)
    rng = np.random.default_rng(7)
    table = BenchmarkTable.new()
    for pt in range(3):
        for m in _SMOKE_METHODS:
            for ps_id in ("p1", "p2"):
                table.add(ds.name, pt, m, ps_id,
                          recall=float(rng.uniform(0.7, 1.0)),
                          qps=float(rng.uniform(100, 2000)))
    models = {m: mlp_mod.params_to_numpy(
        mlp_mod.init_mlp((5, 64, 32, 1), jax.random.PRNGKey(j)))
        for j, m in enumerate(_SMOKE_METHODS)}
    router = MLRouter(feature_names=F.MINIMAL_FEATURES,
                      methods=_SMOKE_METHODS, models=models,
                      scaler=mlp_mod.Scaler(np.zeros(5), np.ones(5)),
                      table=table)
    return ds, router


def _legacy_route(router: MLRouter, ds, dsf, qbms, pred, t: float):
    """Faithful replica of the seed per-query routing pipeline."""
    rows = []
    for qi in range(qbms.shape[0]):          # Q host scans (seed hot loop)
        qf = F.query_features(ds, dsf, qbms[qi], pred)
        row = []
        for name in router.feature_names:
            if name == "pred":
                row.extend([float(int(Predicate(pred)) == i) for i in range(3)])
            elif name in F.QUERY_FEATURES:
                row.append(qf[name])
            else:
                row.append(dsf.values[name])
        rows.append(row)
    xs = router.scaler.transform(np.asarray(rows, dtype=np.float32))
    r_hat = np.stack([mlp_mod.forward_np(router.models[m], xs)[:, 0]
                      for m in router.methods], axis=1)
    return router.route_from_predictions_loop(r_hat, ds.name, pred, t)


def run(verbose=True, q_batch: int = 1024, t: float = 0.9, smoke: bool = False):
    if smoke:
        ds, router = _smoke_setup()
        ds_names = [ds.name]
        get_ds = lambda name: ds
    else:
        _, coll_val, router = load_artifacts(verbose=False)
        ds_names = sorted({k[0] for k in coll_val.cells})
        get_ds = get_dataset

    rows = []
    for ds_name in ds_names:
        ds = get_ds(ds_name)
        svc = RouterService(default_index(ds), router, t=t)
        dsf = F.dataset_features(ds)
        for pred in PREDICATES:
            qs = make_queries(ds, pred, q_batch, seed=23,
                              with_ground_truth=False)
            batch = QueryBatch(qs.vectors, qs.bitmaps, pred, k=10)
            # warm both paths at full batch shape (jit compile, feature cache)
            svc.route(batch)
            _legacy_route(router, ds, dsf, qs.bitmaps[:8], pred, t)

            t0 = time.perf_counter()
            legacy = _legacy_route(router, ds, dsf, qs.bitmaps, pred, t)
            t1 = time.perf_counter()

            # batched path with component breakdown — best of 3 (the
            # --check gate compares this across runs; a single sample is
            # hostage to scheduler noise on a shared host). Components
            # are taken from the best rep so they add up.
            best = None
            for _ in range(3):
                tf0 = time.perf_counter()
                x = F.feature_matrix(ds, qs.bitmaps, pred,
                                     router.feature_names)
                tf1 = time.perf_counter()
                r_hat = router.predict_recalls_from_features(x)
                tf2 = time.perf_counter()
                batched = router.route_from_predictions(r_hat, ds.name,
                                                        pred, t)
                tf3 = time.perf_counter()
                if best is None or tf3 - tf0 < best[0]:
                    best = (tf3 - tf0, tf1 - tf0, tf2 - tf1, tf3 - tf2,
                            r_hat, batched)
            total_s, feat_s, fwd_s, alg2_s, r_hat, batched = best

            # parity: the vectorised Algorithm 2 must match the seed loop
            # exactly *on the same predictions* (the two MLP forwards —
            # numpy vs XLA — may differ in the last ulp near the threshold,
            # so cross-forward decision drift is reported, not asserted)
            assert batched == router.route_from_predictions_loop(
                r_hat, ds.name, pred, t), \
                "vectorised Algorithm 2 diverged from the per-query loop"
            drift = sum(a != b for a, b in zip(legacy, batched))
            legacy_us = (t1 - t0) * 1e6
            batched_us = total_s * 1e6
            # paper §6.3 reference: routing overhead relative to the median
            # per-query search latency from the offline table B
            search_us = [1e6 / max(v["qps"], 1e-9)
                         for (d, p, _, _), v in router.table.entries.items()
                         if d == ds_name and p == int(pred)]
            med_search = float(np.median(search_us)) if search_us else float("nan")
            rows.append({
                "dataset": ds_name, "pred": pred.name, "q": q_batch,
                "legacy_us": round(legacy_us, 1),
                "batched_us": round(batched_us, 1),
                "speedup": round(legacy_us / batched_us, 2),
                "features_us": round(feat_s * 1e6, 1),
                "forward_us": round(fwd_s * 1e6, 1),
                "alg2_us": round(alg2_s * 1e6, 1),
                "per_query_us": round(batched_us / q_batch, 3),
                "median_search_us": round(med_search, 1),
                "routing_ratio_pct": round(
                    100 * (batched_us / q_batch) / med_search, 2),
                "decision_drift": drift,
            })
            if verbose:
                r = rows[-1]
                print(f"  {ds_name:12s} {pred.name:8s} Q={q_batch} "
                      f"legacy={r['legacy_us']:10.1f}us "
                      f"batched={r['batched_us']:9.1f}us "
                      f"({r['speedup']}x; feat {r['features_us']} + "
                      f"fwd {r['forward_us']} + alg2 {r['alg2_us']}) "
                      f"ratio={r['routing_ratio_pct']}% "
                      f"drift={r['decision_drift']}",
                      flush=True)
    if verbose:
        sp = np.array([r["speedup"] for r in rows])
        print(f"  median speedup over seed per-query routing: "
              f"{float(np.median(sp)):.1f}x  (min {float(sp.min()):.1f}x)",
              flush=True)
    path = emit(rows, "routing_latency")
    return rows, path
