"""Paper §6.3 routing-latency breakdown: bitmap selectivity + feature
scaling + 5 MLP forwards + table lookup, per predicate type; median / p95 /
max across all validation queries, and the routing-to-query latency ratio."""

from __future__ import annotations

import time

import numpy as np

from repro.ann.predicates import Predicate
from repro.core import features as F
from repro.core import mlp as mlp_mod
from repro.core import training as T
from repro.data.ann_synth import get_dataset, make_queries

from benchmarks.common import emit, load_artifacts


def run(verbose=True, n_queries: int = 100):
    _, coll_val, router = load_artifacts(verbose=False)
    params = [router.models[m] for m in router.methods]
    per_query, comp = [], {"selectivity": [], "forwards": [], "lookup": []}
    for ds_name in sorted({k[0] for k in coll_val.cells}):
        ds = get_dataset(ds_name)
        dsf = F.dataset_features(ds)
        for pred in Predicate:
            qs = make_queries(ds, pred, n_queries, seed=23,
                              with_ground_truth=False)
            pt = int(pred)
            ps_cache = {m: router.table.best_qps_setting(ds_name, pt, m, 0.9)
                        for m in router.methods}
            for qi in range(qs.q):
                t0 = time.perf_counter()
                sel = ds.selectivity(qs.bitmaps[qi], pred)      # bitmap step
                t1 = time.perf_counter()
                x = np.array([[sel, dsf.values["lid_mean"],
                               pred == 0, pred == 1, pred == 2]],
                             dtype=np.float32)
                xs = router.scaler.transform(x)
                r_hat = [float(mlp_mod.forward_np(p, xs)[0, 0])
                         for p in params]
                t2 = time.perf_counter()
                passing = [m for m, r in zip(router.methods, r_hat)
                           if r >= 0.9 and ps_cache[m] is not None]
                if passing:
                    max(passing, key=lambda m: ps_cache[m][1]["qps"])
                else:
                    router.methods[int(np.argmax(r_hat))]
                t3 = time.perf_counter()
                comp["selectivity"].append((t1 - t0) * 1e6)
                comp["forwards"].append((t2 - t1) * 1e6)
                comp["lookup"].append((t3 - t2) * 1e6)
                per_query.append((t3 - t0) * 1e6)
    per_query = np.array(per_query)
    # search latency reference: median per-query search time from table B
    search_lat = []
    for (ds, pt), cell in coll_val.cells.items():
        for m, ps_id, rec, qps in cell.sweep:
            search_lat.append(1e6 / max(qps, 1e-9))
    rows = [{
        "median_us": round(float(np.median(per_query)), 1),
        "p95_us": round(float(np.percentile(per_query, 95)), 1),
        "max_us": round(float(per_query.max()), 1),
        "selectivity_med_us": round(float(np.median(comp["selectivity"])), 1),
        "mlp_forwards_med_us": round(float(np.median(comp["forwards"])), 1),
        "lookup_med_us": round(float(np.median(comp["lookup"])), 1),
        "median_search_us": round(float(np.median(search_lat)), 1),
        "routing_ratio_pct": round(100 * float(np.median(per_query)) /
                                   float(np.median(search_lat)), 2)}]
    if verbose:
        r = rows[0]
        print(f"  routing: median={r['median_us']}us p95={r['p95_us']}us "
              f"max={r['max_us']}us  (sel {r['selectivity_med_us']} + "
              f"mlp {r['mlp_forwards_med_us']} + lookup "
              f"{r['lookup_med_us']})  ratio={r['routing_ratio_pct']}%",
              flush=True)
    path = emit(rows, "routing_latency")
    return rows, path
