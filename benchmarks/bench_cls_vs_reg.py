"""Paper Table 6 (§6.2b): classification vs regression model families,
plain-argmax evaluation (same features, training set, capacity), plus
per-family inference latency (the Ridge/MLP-Reg/RF-Reg comparison)."""

from __future__ import annotations

import numpy as np

from repro.core import features as F
from repro.core import training as T
from repro.core.baselines import BestMethodClassifier, PerMethodRegressor
from repro.core.mlp import Scaler

from benchmarks.common import emit, load_artifacts, timeit_us

FAMILIES = [
    ("classification", "LogisticReg", "logistic"),
    ("classification", "MLP", "mlp"),
    ("classification", "RandomForest", "rf"),
    ("regression", "Ridge", "ridge"),
    ("regression", "MLP-Reg", "mlp"),
    ("regression", "RF-Reg", "rf"),
]


def run(verbose=True):
    coll_train, coll_val, _ = load_artifacts(verbose=False)
    feats = F.MINIMAL_FEATURES
    x_tr, y_tr, _ = T.assemble_xy(coll_train, feats)
    scaler = Scaler.fit(x_tr)
    xs_tr = scaler.transform(x_tr)
    best_tr = y_tr.argmax(axis=1)

    rows = []
    for family, label, kind in FAMILIES:
        if family == "classification":
            model = BestMethodClassifier(kind, len(T.METHOD_ORDER)).fit(
                xs_tr, best_tr)
            choose = lambda xs: model.predict(xs)
        else:
            model = PerMethodRegressor(kind).fit(xs_tr, y_tr)
            choose = lambda xs: model.predict(xs).argmax(1)

        per_ds, agg = {}, []
        for (ds, pt), cell in coll_val.cells.items():
            x, y, _ = T.assemble_xy(
                T.Collection(cells={(ds, pt): cell}, table=coll_val.table),
                feats)
            picks = choose(scaler.transform(x))
            rec = [cell.recall[T.METHOD_ORDER[p]][i]
                   for i, p in enumerate(picks)]
            per_ds.setdefault(ds, []).extend(rec)
            agg.extend(rec)
        # inference latency per query (batch-1 calls)
        x1 = xs_tr[:1]
        lat = timeit_us(choose, x1, repeat=7, number=5) / 5
        rows.append({
            "family": family, "model": label,
            "yahoo800k": round(float(np.mean(per_ds["yahoo800k"])), 4),
            "dbpedia560k": round(float(np.mean(per_ds["dbpedia560k"])), 4),
            "aggregate": round(float(np.mean(agg)), 4),
            "us_per_query": round(lat, 2)})
        if verbose:
            r = rows[-1]
            print(f"  {family:14s} {label:12s} agg={r['aggregate']:.4f} "
                  f"yahoo={r['yahoo800k']:.4f} dbp={r['dbpedia560k']:.4f} "
                  f"{r['us_per_query']:8.2f} us/q", flush=True)
    path = emit(rows, "table6_cls_vs_reg")
    return rows, path
