"""Sharded-service timing: one batched exact search through
`ShardedFilteredIndex` at increasing shard counts, against the
single-index baseline (shards=1).

On a multi-device host each shard owns its device and executes in
parallel; on this CPU container every shard lands on the one device, so
the harness measures the partition + per-shard dispatch + `merge_topk`
overhead — the quantity the smoke trajectory gates (a regression here
means the sharding layer itself got more expensive, independent of
device parallelism).
"""

from __future__ import annotations

import numpy as np

from repro.ann.index import QueryBatch
from repro.ann.predicates import Predicate
from repro.ann.sharded import ShardedFilteredIndex
from repro.data.ann_synth import DatasetSpec, make_queries, synthesize

from benchmarks.common import emit, timeit_best_us

_SPEC = DatasetSpec("bench_shard", 8192, 32, 60, 8, 16, 1.3, 2.0, 0.5, 0.3, 13)
_SMOKE_SPEC = DatasetSpec("bench_shard_smoke", 2048, 32, 60, 8, 16,
                          1.3, 2.0, 0.5, 0.3, 13)


def run(verbose=True, smoke: bool = False, q: int | None = None,
        shard_counts=None):
    if smoke:
        spec, q, shard_counts = _SMOKE_SPEC, q or 64, shard_counts or (1, 2)
    else:
        spec, q, shard_counts = _SPEC, q or 128, shard_counts or (1, 2, 4)
    ds = synthesize(spec)
    qs = make_queries(ds, Predicate.AND, q, seed=3)
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    rows = []
    base_ids = None
    for s in shard_counts:
        with ShardedFilteredIndex(ds, s) as sfx:
            res = sfx.search(batch, "prefilter")        # warm-up + build
            if base_ids is None:
                base_ids = res.ids
            else:                                        # partition sanity
                assert np.array_equal(res.ids, base_ids)
            batch_us = timeit_best_us(
                lambda: sfx.search(batch, "prefilter"), repeat=5)
        rows.append({"shards": s, "n": ds.n, "q": q,
                     "batch_us": round(batch_us, 1),
                     "per_query_us": round(batch_us / q, 2)})
        if verbose:
            print(f"  shards={s} n={ds.n} q={q}: "
                  f"{batch_us / 1e3:.1f} ms/batch "
                  f"({batch_us / q:.0f} us/query)", flush=True)
    path = emit(rows, "sharded_service")
    return rows, path
