"""Live-index benchmark: upsert throughput and search latency under
concurrent write load.

Three gated numbers per (n, q) row:

* ``upsert_us_per_row`` — streaming ingest cost (append + tombstone +
  live-label-count bookkeeping), measured over batched upserts;
* ``search_sealed_us`` — batched exact search on the untouched live
  handle (the no-write floor; should track the plain ``FilteredIndex``
  path modulo the merge fold);
* ``search_live_us`` — the same search while a writer thread streams
  upserts into the delta segment, i.e. what a reader pays when the
  index is taking writes (base scan + delta scan + merge, with the
  delta device mirror absorbing the sealed chunks).

All three are lower-is-better, so the ``--check`` regression gate
compares them uniformly.
"""

from __future__ import annotations

import threading

import numpy as np

from repro.ann.index import QueryBatch
from repro.ann.live import LiveFilteredIndex
from repro.ann.predicates import Predicate
from repro.data.ann_synth import DatasetSpec, make_queries, synthesize

from benchmarks.common import emit, timeit_best_us

_SPEC = DatasetSpec("bench_live", 8192, 32, 60, 8, 16, 1.3, 2.0, 0.5, 0.3, 17)
_SMOKE_SPEC = DatasetSpec("bench_live_smoke", 2048, 32, 60, 8, 16,
                          1.3, 2.0, 0.5, 0.3, 17)


def run(verbose=True, smoke: bool = False, q: int | None = None,
        write_rows: int | None = None):
    if smoke:
        spec, q, write_rows = _SMOKE_SPEC, q or 64, write_rows or 512
    else:
        spec, q, write_rows = _SPEC, q or 128, write_rows or 2048
    ds = synthesize(spec)
    qs = make_queries(ds, Predicate.AND, q, seed=5)
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    rng = np.random.default_rng(23)
    new_vec = (ds.vectors[rng.integers(0, ds.n, write_rows)]
               + rng.normal(scale=0.01, size=(write_rows, ds.dim))
               .astype(np.float32))
    new_bm = ds.bitmaps[rng.integers(0, ds.n, write_rows)]

    rows = []
    with LiveFilteredIndex(ds) as live:
        live.search(batch, "prefilter")           # warm-up + compile
        sealed_us = timeit_best_us(
            lambda: live.search(batch, "prefilter"), repeat=5)

        # upsert throughput: batched 64-row appends into the delta
        def ingest():
            for s in range(0, write_rows, 64):
                live.upsert(new_vec[s: s + 64], new_bm[s: s + 64])

        t_ingest = timeit_best_us(ingest, repeat=1)
        upsert_us = t_ingest / write_rows
        # warm the delta path at its steady shape before timing readers
        live.search(batch, "prefilter")

        # search latency while a writer streams more rows in. The write
        # budget stays below one delta mirror chunk so the kernel shapes
        # are stable and the gate measures contention, not recompiles.
        import time as _time

        stop = threading.Event()
        budget = live._delta.chunk - 1

        def writer():
            for i in range(budget):
                if stop.is_set():
                    return
                live.upsert(new_vec[i % write_rows: i % write_rows + 1],
                            new_bm[i % write_rows: i % write_rows + 1])
                _time.sleep(0.0005)

        th = threading.Thread(target=writer, daemon=True)
        th.start()
        try:
            live_us = timeit_best_us(
                lambda: live.search(batch, "prefilter"), repeat=5)
        finally:
            stop.set()
            th.join(timeout=30)
        delta_rows = live.stats()["delta_rows"]

    rows.append({"n": ds.n, "q": q, "delta_rows": int(delta_rows),
                 "upsert_us_per_row": round(upsert_us, 2),
                 "search_sealed_us": round(sealed_us, 1),
                 "search_live_us": round(live_us, 1)})
    if verbose:
        r = rows[-1]
        print(f"  n={r['n']} q={q}: upsert {r['upsert_us_per_row']:.1f} "
              f"us/row, search sealed {sealed_us / 1e3:.1f} ms -> live "
              f"{live_us / 1e3:.1f} ms (delta={r['delta_rows']} rows)",
              flush=True)
    path = emit(rows, "live_index")
    return rows, path
