"""Live-index benchmark: upsert throughput and search latency under
concurrent write load.

Three gated numbers per (n, q) row:

* ``upsert_us_per_row`` — streaming ingest cost (append + tombstone +
  live-label-count bookkeeping), measured over batched upserts;
* ``search_compacted_us`` — batched exact search on the *same corpus*
  (base + every written row) after ``compact()`` folded it into a
  sealed base. This is the fair floor: the index serves identical
  rows, just without a delta segment;
* ``search_live_us`` — the same search on the live handle holding
  those rows as a delta segment at 50 % of the base row count, while
  a writer thread keeps streaming (the fused single-launch path folds
  base + delta + tombstones in one kernel);
* ``live_sealed_ratio`` — ``search_live_us / search_compacted_us`` at
  that 50 % delta fill: the pure cost of *liveness* (delta scan +
  tombstone masking + merge), with the extra-rows cost divided out
  because both sides serve the same corpus. The acceptance bar for
  the fused read path is ratio <= 1.5, gated absolutely by
  ``--check``.

``run_compaction`` times ``compact()`` (graft mode) at two base sizes;
the wall-clock ratio must stay below the size ratio — grafting splices
the existing method indexes instead of rebuilding them, so compaction
cost is sublinear in base size.

All gated numbers are lower-is-better, so the ``--check`` regression
gate compares them uniformly.
"""

from __future__ import annotations

import threading

import numpy as np

from repro.ann.index import QueryBatch
from repro.ann.live import LiveFilteredIndex
from repro.ann.predicates import Predicate
from repro.data.ann_synth import DatasetSpec, make_queries, synthesize

from benchmarks.common import emit, timeit_best_us

_SPEC = DatasetSpec("bench_live", 8192, 32, 60, 8, 16, 1.3, 2.0, 0.5, 0.3, 17)
_SMOKE_SPEC = DatasetSpec("bench_live_smoke", 2048, 32, 60, 8, 16,
                          1.3, 2.0, 0.5, 0.3, 17)


def run(verbose=True, smoke: bool = False, q: int | None = None,
        write_rows: int | None = None):
    # default write budget = half the base rows, so the gated live
    # measurement lands at the acceptance point: 50 % delta fill
    if smoke:
        spec, q = _SMOKE_SPEC, q or 64
    else:
        spec, q = _SPEC, q or 128
    write_rows = write_rows or spec.n // 2
    ds = synthesize(spec)
    qs = make_queries(ds, Predicate.AND, q, seed=5)
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    rng = np.random.default_rng(23)
    new_vec = (ds.vectors[rng.integers(0, ds.n, write_rows)]
               + rng.normal(scale=0.01, size=(write_rows, ds.dim))
               .astype(np.float32))
    new_bm = ds.bitmaps[rng.integers(0, ds.n, write_rows)]

    rows = []
    # compacted reference: the same corpus (base + all written rows)
    # folded into a sealed base — the floor for the read-gap ratio
    with LiveFilteredIndex(ds) as ref:
        ref.upsert(new_vec, new_bm)
        ref.compact()
        ref.search(batch, "prefilter")            # warm-up + compile
        compacted_us = timeit_best_us(
            lambda: ref.search(batch, "prefilter"), repeat=5)

    with LiveFilteredIndex(ds) as live:
        live.search(batch, "prefilter")           # warm-up + compile

        # upsert throughput: batched 64-row appends into the delta
        def ingest():
            for s in range(0, write_rows, 64):
                live.upsert(new_vec[s: s + 64], new_bm[s: s + 64])

        t_ingest = timeit_best_us(ingest, repeat=1)
        upsert_us = t_ingest / write_rows
        # warm the delta path at its steady shape before timing readers
        live.search(batch, "prefilter")

        # search latency while a writer streams more rows in. The write
        # budget stays below one delta mirror chunk so the kernel shapes
        # are stable and the gate measures contention, not recompiles.
        # Writes arrive in 8-row bursts with quiet windows between them
        # (the common batched-ingest shape); best-of timing then reports
        # the steady-state read cost at this fill, with the bursts
        # exercising the lock/snapshot contention path.
        import time as _time

        stop = threading.Event()
        budget = live._delta.chunk - 1

        def writer():
            for s in range(0, budget, 8):
                if stop.is_set():
                    return
                e = min(s + 8, budget)
                live.upsert(new_vec[s:e], new_bm[s:e])
                _time.sleep(0.02)

        th = threading.Thread(target=writer, daemon=True)
        th.start()
        try:
            live_us = timeit_best_us(
                lambda: live.search(batch, "prefilter"), repeat=20)
        finally:
            stop.set()
            th.join(timeout=30)
        delta_rows = live.stats()["delta_rows"]

    rows.append({"n": ds.n, "q": q, "delta_rows": int(delta_rows),
                 "upsert_us_per_row": round(upsert_us, 2),
                 "search_compacted_us": round(compacted_us, 1),
                 "search_live_us": round(live_us, 1),
                 "live_sealed_ratio": round(live_us / compacted_us, 3)})
    if verbose:
        r = rows[-1]
        print(f"  n={r['n']} q={q}: upsert {r['upsert_us_per_row']:.1f} "
              f"us/row, search compacted {compacted_us / 1e3:.1f} ms -> "
              f"live {live_us / 1e3:.1f} ms = {r['live_sealed_ratio']:.2f}x "
              f"(delta={r['delta_rows']} rows)",
              flush=True)
    path = emit(rows, "live_index")
    return rows, path


_COMPACT_NS = (4096, 65536)
_SMOKE_COMPACT_NS = (1024, 16384)
_COMPACT_WRITES = 64          # fixed write load — we scale the BASE only
_COMPACT_REPEAT = 3


def run_compaction(verbose=True, smoke: bool = False):
    """Graft-compaction wall-clock at two base sizes.

    Each handle carries one built method index (ivf_gamma) as the graft
    donor; every repetition upserts/deletes a *fixed* number of rows and
    compacts, so the only thing growing between the two rows is the
    base. Grafting splices the donor through the id remap instead of
    re-running k-means, so wall-clock is fixed-overhead + O(n) repack —
    sublinear in the measured range: ``scaling_vs_linear`` =
    (t2/t1) / (n2/n1) < 1. Best-of-N per size (single-shot compaction
    timings are noisy at the millisecond scale).
    """
    import time as _time

    sizes = _SMOKE_COMPACT_NS if smoke else _COMPACT_NS
    rows = []
    for n in sizes:
        spec = DatasetSpec(f"bench_compact_{n}", n, 32, 60, 8, 16,
                           1.3, 2.0, 0.5, 0.3, 17)
        ds = synthesize(spec)
        qs = make_queries(ds, Predicate.AND, 16, seed=5)
        batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
        rng = np.random.default_rng(29)
        best_ms = np.inf
        with LiveFilteredIndex(ds) as live:
            live.search(batch, "ivf_gamma")       # builds the graft donor
            for _ in range(_COMPACT_REPEAT):      # graft persists per gen
                pick = rng.integers(0, n, _COMPACT_WRITES)
                live.upsert(ds.vectors[pick] + np.float32(0.01),
                            ds.bitmaps[pick])
                live.delete(rng.choice(n, _COMPACT_WRITES // 2,
                                       replace=False))
                t0 = _time.perf_counter()
                live.compact()
                best_ms = min(best_ms,
                              (_time.perf_counter() - t0) * 1e3)
        # scaling relative to the smallest base: wall-clock growth over
        # row-count growth; < 1 means sublinear (first row trivially 1)
        t_ratio = best_ms / max(rows[0]["compact_ms"], 1e-9) if rows else 1.0
        n_ratio = n / sizes[0]
        rows.append({"n_base": n, "delta_rows": _COMPACT_WRITES,
                     "deletes": _COMPACT_WRITES // 2,
                     "compact_ms": round(best_ms, 2),
                     "scaling_vs_linear": round(t_ratio / n_ratio, 3)})
        if verbose:
            print(f"  n_base={n}: compact {best_ms:.1f} ms "
                  f"(writes={_COMPACT_WRITES}, "
                  f"{rows[-1]['scaling_vs_linear']:.2f} of linear)",
                  flush=True)
    path = emit(rows, "live_compaction")
    return rows, path
