"""Paper Table 7 (§6.2c): MLP depth ablation — routing recall and per-query
inference latency for 2/3/4 hidden layers."""

from __future__ import annotations

import numpy as np

from repro.core import features as F
from repro.core import training as T

from benchmarks.common import emit, load_artifacts, timeit_us
from benchmarks.bench_feature_ablation import routed_recall

DEPTHS = {2: (64, 32), 3: (64, 32, 16), 4: (64, 32, 16, 8)}


def run(verbose=True):
    coll_train, coll_val, _ = load_artifacts(verbose=False)
    rows = []
    for depth, hidden in DEPTHS.items():
        models, scaler = T.train_models(coll_train, F.MINIMAL_FEATURES,
                                        seed=0, hidden=hidden, epochs=120)
        rec = routed_recall(coll_val, models, scaler, F.MINIMAL_FEATURES,
                            table=coll_train.table)
        # per-query latency: 5 method forwards on a single feature vector
        # (production numpy inference path — see core/mlp.forward_np)
        from repro.core import mlp as mlp_mod
        import numpy as _np
        params = [models[m] for m in T.METHOD_ORDER]
        x1 = _np.zeros((1, 5), _np.float32)

        def five_forwards(x):
            for p in params:
                mlp_mod.forward_np(p, x)

        five_forwards(x1)   # warm
        lat = timeit_us(five_forwards, x1, repeat=9, number=50) / 50
        rows.append({"hidden_layers": depth, "recall": round(rec, 4),
                     "us_per_query": round(lat, 2)})
        if verbose:
            print(f"  depth={depth} recall={rec:.4f} {lat:7.2f} us/q",
                  flush=True)
    path = emit(rows, "table7_depth")
    return rows, path
