"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh)
from the compiled dry-run artifacts.

  compute term    = dot_FLOPs_per_chip / 197 TFLOP/s (bf16)
  memory term     = HBM_bytes_per_chip / 819 GB/s
  collective term = collective_bytes_per_chip / 50 GB/s per link

(Post-partitioning HLO shapes are per-device; dividing per-chip quantities
by per-chip rates equals the global formula `X_global / (chips × rate)`.)

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); the ratio
MODEL_FLOPS / (HLO_FLOPs × chips) exposes remat/redundancy waste.
"""

from __future__ import annotations

import json
import os

import jax

from repro.common import artifacts_dir
from repro.configs.base import SHAPES, ARCH_IDS, get_config
from repro.models import common, lm

from benchmarks.common import emit

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link

MESHES = {"16x16": 256, "2x16x16": 512}


def active_params(arch: str) -> tuple[int, int]:
    """(total params, active-per-token params)."""
    cfg = get_config(arch)
    desc = lm.model_desc(cfg)
    total = common.count_params(desc)
    if not cfg.is_moe:
        return total, total
    flat = jax.tree_util.tree_flatten_with_path(
        desc, is_leaf=common.is_desc)[0]
    routed = 0
    for path, d in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        if "moe" in keys and any(k in ("w_gate", "w_up", "w_down")
                                 for k in keys):
            n = 1
            for s in d.shape:
                n *= s
            routed += n
    active = total - routed + routed * cfg.experts_per_token / cfg.n_experts
    return total, int(active)


def model_flops(arch: str, shape_name: str) -> float:
    shape = SHAPES[shape_name]
    _, n_active = active_params(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: 1 token/seq


def bottleneck_advice(dom: str, cell: dict) -> str:
    kinds = cell.get("collective_by_kind", {})
    top_coll = max(kinds, key=kinds.get) if kinds else ""
    return {
        "compute": "compute-bound: raise MXU utilisation (larger fused "
                   "matmul tiles, bf16 end-to-end) or shrink redundant "
                   "recompute (remat policy)",
        "memory": "HBM-bound: raise arithmetic intensity — fuse the "
                  "attention softmax chain, keep activations bf16, widen "
                  "the per-step tile reuse",
        "collective": f"collective-bound (dominant: {top_coll}): constrain "
                      "activation shardings so TP reduces over d_model not "
                      "fused QKV/FFN width; overlap via latency-hiding "
                      "scheduler / async collectives",
    }[dom]


def run(verbose=True):
    d = artifacts_dir("dryrun")
    rows = []
    for arch in ARCH_IDS:
        for shape_name in SHAPES:
            for mesh_name, chips in MESHES.items():
                path = os.path.join(d, f"{arch}_{shape_name}_{mesh_name}.json")
                if not os.path.exists(path):
                    continue
                with open(path) as f:
                    cell = json.load(f)
                if cell["status"] != "ok":
                    rows.append({"arch": arch, "shape": shape_name,
                                 "mesh": mesh_name, "status": cell["status"],
                                 "note": cell.get("reason", "")[:60]})
                    continue
                t_c = cell["hlo_dot_flops"] / PEAK_FLOPS
                t_m = cell["hlo_hbm_bytes"] / HBM_BW
                t_x = cell["collective_bytes"] / LINK_BW
                terms = {"compute": t_c, "memory": t_m, "collective": t_x}
                dom = max(terms, key=terms.get)
                mf = model_flops(arch, shape_name)
                hlo_global = cell["hlo_dot_flops"] * chips
                rows.append({
                    "arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": "ok",
                    "compute_s": f"{t_c:.3e}",
                    "memory_s": f"{t_m:.3e}",
                    "collective_s": f"{t_x:.3e}",
                    "dominant": dom,
                    "roofline_frac": round(t_c / max(max(terms.values()),
                                                     1e-30), 3),
                    "model_flops": f"{mf:.3e}",
                    "useful_ratio": round(mf / max(hlo_global, 1e-30), 3),
                    "note": bottleneck_advice(dom, cell)[:70],
                })
                if verbose:
                    r = rows[-1]
                    print(f"  {arch:18s} {shape_name:12s} {mesh_name:8s} "
                          f"C={r['compute_s']} M={r['memory_s']} "
                          f"X={r['collective_s']} dom={dom:10s} "
                          f"frac={r['roofline_frac']:5.3f} "
                          f"useful={r['useful_ratio']}", flush=True)
    path = emit(rows, "roofline")
    return rows, path
