"""Shared benchmark plumbing: artifact loading, CSV emission."""

from __future__ import annotations

import os
import time

import numpy as np

from repro.common import artifacts_dir, enable_compilation_cache


def load_artifacts(verbose=True):
    """(coll_train, coll_val, router) — built on first use, cached after."""
    enable_compilation_cache()
    from repro.core import training as T

    return T.build_all(verbose=verbose)


def out_path(name: str) -> str:
    return os.path.join(artifacts_dir("bench"), name)


def emit(rows: list[dict], name: str, *, echo_cols=None) -> str:
    """Write rows to artifacts/bench/<name>.csv and echo a preview."""
    if not rows:
        return ""
    cols = list(rows[0].keys())
    path = out_path(name + ".csv")
    with open(path, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(c, "")) for c in cols) + "\n")
    return path


def _sample_times(fn, args, repeat: int, number: int) -> list[float]:
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            fn(*args)
        times.append((time.perf_counter() - t0) / number)
    return times


def timeit_us(fn, *args, repeat: int = 5, number: int = 1) -> float:
    """Median wall time of fn(*args) in microseconds."""
    return float(np.median(_sample_times(fn, args, repeat, number)) * 1e6)


def timeit_best_us(fn, *args, repeat: int = 5, number: int = 1) -> float:
    """Best (min) wall time of fn(*args) in microseconds — the timeit-style
    estimator for smoke numbers that the --check gate compares across
    runs: the minimum is far less sensitive to scheduler interference on
    a shared host than a single sample or the median."""
    return float(min(_sample_times(fn, args, repeat, number)) * 1e6)
