"""End-to-end serving driver (the paper's deployment scenario): a served LM
handles concurrent requests — each request embeds a query, the query-aware
router picks the filtered-ANN method + parameter setting, the engine
retrieves, and the LM generates conditioned on the retrieved ids.

Requests are served through `AsyncBatchQueue`: every request `submit()`s
its single query independently (as concurrent callers would) and the
queue coalesces them into routed micro-batches. `--shards N` swaps the
single `FilteredIndex` for a row-sharded `ShardedFilteredIndex` +
`ShardedRouterService`. `--live` serves a `LiveFilteredIndex`
(`ShardedLiveIndex` with shards) instead and runs a writer thread that
streams upserts/deletes into the corpus *while* requests are in flight,
then compacts and serves one more round from the swapped base.

`--data-dir DIR` makes the corpus durable through `repro.ann.store`:
the first run builds + trains as usual, then persists the corpus, the
router artifact, and every subsequent upsert/delete (write-ahead
logged) under DIR; later runs skip the offline stage entirely and
recover the index — including writes from previous sessions — plus the
version-stamped router from disk. Composes with `--live` and
`--shards N` (the store remembers the shard layout).

`--cache` fronts the service with a `SemanticResultCache`: every
`submit()` probes it before batching (exact-key hits bypass routing and
search entirely; near-duplicate embeddings serve re-scored semantic
hits), only misses flow through the routed pipeline, and the run
replays the request round to report hit/miss/eviction counters. With
`--live`, the concurrent writer's upserts/deletes evict exactly the
entries whose label sets they touch.

`--telemetry` attaches a `TelemetrySink` to the service: every routed
batch records per-query events (method, ps, predicate, latency share,
live generation) and the run prints counters + latency percentiles.
`--online-router` (implies `--telemetry`) additionally runs the
`OnlineRouterAdapter` between request rounds: reservoir-sampled
queries are replayed against the brute-force oracle on a pinned
snapshot, exact recall folds into an EWMA `OnlineBenchmarkTable`, and
if drift crosses the threshold the router retrains off the serving
path and promotes only after shadow-eval (with `--data-dir`, the
promoted artifact links into the store manifest atomically).

`--trace` attaches a `repro.ann.trace.Tracer`: every request grows a
hierarchical span tree (queue wait -> batch assembly -> route ->
execute -> per-shard / live stages), the flight recorder keeps the
worst trees, and the run prints the slowest one and dumps Perfetto
JSON + the flight recorder under artifacts/serve/. `--metrics-port P`
serves Prometheus `/metrics` (sink counters, per-shard cells, span
histograms, cache/queue stats, ledger/SLO/obslog when attached),
`/healthz` (degrades to 503 on queue/WAL backpressure), `/statusz`,
`/debug/ledger` and `/debug/slo` for the run's duration.

`--slo` attaches an `SLOEngine` (implies `--trace` so alerts carry
flight-recorder trace ids): p99-latency, audited-recall-floor and
availability objectives evaluated with multi-window burn-rate
alerting; audit reports from `--online-router` feed the recall
objective. `--obslog` attaches a `WideEventLog`: one JSONL wide event
per request (trace id, route decision, cache provenance, shard
timings, live generation, SLO state) under artifacts/serve/, plus a
post-mortem dumper on SIGUSR2/exit writing flight + ledger + SLO
state. The resource ledger (snapshot pins, retired generations, WAL
backlog, queue depth, cache/delta bytes) is always on — both flags
print its summary at shutdown.

    PYTHONPATH=src python examples/rag_serve.py [--requests 32] \
        [--shards 2] [--live] [--data-dir /tmp/rag-store] \
        [--cache] [--telemetry] [--online-router] \
        [--trace] [--metrics-port 9100] [--slo] [--obslog]
"""

import argparse
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann.index import FilteredIndex
from repro.ann.live import LiveFilteredIndex, ShardedLiveIndex
from repro.ann.predicates import Predicate
from repro.ann.service import (AsyncBatchQueue, RouterService,
                               ShardedRouterService)
from repro.ann.sharded import ShardedFilteredIndex
from repro.ann.store import MANIFEST, IndexStore
from repro.ann import labels as lb
from repro.configs.base import get_smoke_config
from repro.core import training as T
from repro.data.ann_synth import DatasetSpec, synthesize
from repro.launch.mesh import make_mesh_compat
from repro.launch.serve import generate
from repro.models import common, lm


def _open_or_create_store(args, sink=None, tracer=None, slo=None,
                          obslog=None):
    """Recover (or initialise) the durable corpus + router.

    Returns (store, router, service). A recovered store restores the
    live handle — base segment memmap + WAL replay — and the linked,
    version-stamped router artifact; a fresh directory runs the offline
    stage once and persists everything for the next session.
    """
    if os.path.exists(os.path.join(args.data_dir, MANIFEST)):
        store = IndexStore.open(args.data_dir)
        router = store.load_router()
        lfx = store.index
        st = store.stats()
        print(f"restored store: generation {st['index']['generation']}, "
              f"{st['index']['n_live']} live rows, "
              f"{st['replayed_records']} WAL record(s) replayed")
        if isinstance(lfx, ShardedLiveIndex) and lfx.n_shards != \
                args.shards and args.shards > 1:
            print(f"  (store layout wins: {lfx.n_shards} shard(s), "
                  f"ignoring --shards {args.shards})")
    else:
        ds = synthesize(
            DatasetSpec("corpus", 4000, 32, 48, 8, 12, 1.3, 2.0, 0.5,
                        0.3, 7))
        with FilteredIndex(ds) as fx:
            coll = T.collect({"corpus": fx}, n_queries=60, seed=0,
                             verbose=False)
            router = T.train_router(coll, coll.table, epochs=80)
        os.makedirs(args.data_dir, exist_ok=True)
        router_dir = os.path.join(args.data_dir, "router")
        router.save(router_dir)
        store = IndexStore.create(args.data_dir, ds,
                                  n_shards=args.shards,
                                  router_dir=router_dir)
        lfx = store.index
        print(f"created store at {args.data_dir}: {ds.n} vectors, "
              f"router artifact linked")
    svc = (ShardedRouterService(lfx, router, t=0.9, telemetry=sink,
                                tracer=tracer, slo=slo, obslog=obslog)
           if isinstance(lfx, ShardedLiveIndex)
           else RouterService(lfx, router, t=0.9, telemetry=sink,
                              tracer=tracer, slo=slo, obslog=obslog))
    return store, router, svc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--shards", type=int, default=1,
                    help="row shards for the corpus (1 = single index)")
    ap.add_argument("--live", action="store_true",
                    help="serve a live index with a concurrent writer "
                         "thread (streaming upserts/deletes + compaction)")
    ap.add_argument("--data-dir", default=None,
                    help="durable IndexStore directory: restore the "
                         "corpus + router from it on startup (skipping "
                         "the offline stage), persist all writes to it, "
                         "checkpoint on shutdown")
    ap.add_argument("--cache", action="store_true",
                    help="front the service with a SemanticResultCache "
                         "(exact-key + cosine-threshold hits bypass "
                         "routing and search; label-clock invalidation "
                         "under --live) and replay the round to show "
                         "hit/miss/eviction counters")
    ap.add_argument("--telemetry", action="store_true",
                    help="attach a TelemetrySink: per-query events, "
                         "counters, latency percentiles, audit reservoir")
    ap.add_argument("--online-router", action="store_true",
                    help="run the OnlineRouterAdapter (implies "
                         "--telemetry): sampled exact-recall audits fold "
                         "into an EWMA table; drift triggers background "
                         "retrain + shadow-eval + atomic artifact swap")
    ap.add_argument("--trace", action="store_true",
                    help="attach a Tracer: hierarchical spans across "
                         "route/execute/queue/cache/live stages with "
                         "tail-based sampling; prints the slowest span "
                         "tree and dumps Perfetto JSON + the flight "
                         "recorder under artifacts/serve/")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus /metrics and /healthz on this "
                         "port (0 = auto-pick) for the duration of the "
                         "run; composes with --telemetry/--trace/--cache")
    ap.add_argument("--slo", action="store_true",
                    help="attach an SLOEngine (implies --trace): p99 "
                         "latency / audited-recall / availability "
                         "objectives with multi-window burn-rate "
                         "alerting; alerts carry trace ids + table "
                         "version")
    ap.add_argument("--obslog", action="store_true",
                    help="write one JSONL wide event per request "
                         "(trace id, route, cache, timings, SLO state) "
                         "under artifacts/serve/, and install the "
                         "SIGUSR2/atexit post-mortem dumper")
    args = ap.parse_args()
    if args.online_router:
        args.telemetry = True
    if args.slo:
        args.trace = True        # alerts want flight-recorder trace ids
    rng = np.random.default_rng(0)

    # --- corpus + router (offline stage, or store recovery) ---
    from repro.ann.telemetry import OnlineRouterAdapter, TelemetrySink
    sink = (TelemetrySink(capacity=4096, reservoir=128, seed=11)
            if args.telemetry else None)
    tracer = None
    if args.trace:
        from repro.ann.trace import Tracer
        # slow_ms=0: keep everything in this short demo run so the
        # flight recorder and Perfetto dump are never empty
        tracer = Tracer(slow_ms=0.0, sample=1.0, flight_capacity=32,
                        seed=11)
    slo_eng = None
    if args.slo:
        from repro.ann.slo import Objective, SLOEngine
        # demo-scale alert windows (seconds, not hours) so a single
        # short run exercises the full observe -> burn -> alert path
        slo_eng = SLOEngine(
            [Objective(name="latency_p99", kind="latency", target=0.99,
                       threshold_us=50_000.0,
                       description="<=1% of queries slower than 50 ms"),
             Objective(name="recall_floor", kind="recall", target=0.90,
                       floor=0.80,
                       description="<=10% of audited samples below 0.80"),
             Objective(name="availability", kind="availability",
                       target=0.999)],
            windows=((60.0, 5.0, 2.0),), min_events=8, tracer=tracer)
    obslog = None
    if args.obslog:
        from repro.ann.obslog import WideEventLog
        from repro.common import artifacts_dir
        obslog = WideEventLog(os.path.join(artifacts_dir("serve"),
                                           "wide_events.jsonl"))
    store = None
    if args.data_dir:
        store, router, svc = _open_or_create_store(args, sink, tracer,
                                                   slo_eng, obslog)
        ds = svc.index.ds        # the recovered sealed base
    else:
        spec = DatasetSpec("corpus", 4000, 32, 48, 8, 12, 1.3, 2.0, 0.5,
                           0.3, 7)
        ds = synthesize(spec)
        fx = FilteredIndex(ds)
        coll = T.collect({"corpus": fx}, n_queries=60, seed=0,
                         verbose=False)
        router = T.train_router(coll, coll.table, epochs=80)
        if args.live:
            fx.close()           # the live handle owns its own tensors
            lfx = (ShardedLiveIndex(ds, args.shards) if args.shards > 1
                   else LiveFilteredIndex(ds))
            svc = (ShardedRouterService(lfx, router, t=0.9, telemetry=sink,
                                        tracer=tracer, slo=slo_eng,
                                        obslog=obslog)
                   if args.shards > 1
                   else RouterService(lfx, router, t=0.9, telemetry=sink,
                                      tracer=tracer, slo=slo_eng,
                                      obslog=obslog))
        elif args.shards > 1:
            fx.close()           # collect() is done; shards own their tensors
            sfx = ShardedFilteredIndex(ds, args.shards)
            svc = ShardedRouterService(sfx, router, t=0.9, telemetry=sink,
                                       tracer=tracer, slo=slo_eng,
                                       obslog=obslog)
        else:
            svc = RouterService(fx, router, t=0.9, telemetry=sink,
                                tracer=tracer, slo=slo_eng, obslog=obslog)
    serving = svc
    if args.cache:
        from repro.ann.cache import SemanticResultCache
        serving = SemanticResultCache(svc, threshold=0.98, capacity=2048)
    from repro.ann.ledger import get_ledger
    postmortem = None
    if args.obslog:
        from repro.ann.obslog import install_postmortem
        postmortem = install_postmortem(tracer=tracer, ledger=get_ledger(),
                                        slo=slo_eng, obslog=obslog)
        print(f"obslog: wide events -> {obslog.path} "
              f"(post-mortem on SIGUSR2/exit)")
    metrics_srv = None
    if args.metrics_port is not None:
        from repro.ann.metrics import (MetricsServer, backpressure_health,
                                       metrics_text)
        cache_obj = serving if args.cache else None
        # service=svc late-binds the router's table: once the online
        # adapter swaps in its OnlineBenchmarkTable, scrapes pick up
        # the shard-keyed EWMA cells without rebuilding the closure
        metrics_srv = MetricsServer(
            lambda: metrics_text(sink=sink, tracer=tracer,
                                 cache=cache_obj, ledger=get_ledger(),
                                 slo=slo_eng, obslog=obslog,
                                 service=svc),
            port=args.metrics_port,
            health=backpressure_health(
                wal=getattr(store, "_wal", None)),
            ledger=get_ledger(), slo=slo_eng, obslog=obslog)
        print(f"metrics: {metrics_srv.url}/metrics + /healthz + "
              f"/statusz + /debug/ledger + /debug/slo")
    print(f"corpus: {ds.n} vectors ({args.shards} shard(s), "
          f"live={args.live}, durable={bool(args.data_dir)}, "
          f"cache={args.cache}); router "
          f"ready ({len(router.table.entries)} table entries)")

    # --- served LM (reduced config; embeddings from its hidden states) ---
    cfg = get_smoke_config(args.arch)
    params = common.init_params(lm.model_desc(cfg), jax.random.PRNGKey(0))
    ctx = lm.ModelCtx(mesh=make_mesh_compat((1, 1), ("data", "model")),
                      qc_prefill=32, gla_chunk=32)
    embed_fn = jax.jit(lambda p, b: lm.forward_prefill(p, b, cfg, ctx))

    # --- batched requests: prompt tokens + label predicate ---
    b = args.requests
    prompts = jnp.asarray(rng.integers(1, 400, size=(b, 32)), jnp.int32)
    preds = [Predicate(int(p)) for p in rng.integers(0, 3, size=b)]
    qbms = np.zeros((b, ds.bitmaps.shape[1]), np.uint32)
    for i in range(b):
        src = sorted(lb.unpack_one(ds.bitmaps[rng.integers(0, ds.n)]))
        take = src[: 1 + int(preds[i] == Predicate.OR)]
        qbms[i] = lb.pack_one(take, ds.universe)

    t0 = time.perf_counter()
    with ctx.mesh:
        logits, _ = embed_fn(params, {"tokens": prompts})
    emb = np.asarray(logits[:, 0, : ds.dim], np.float32)   # query embeddings
    t_embed = time.perf_counter() - t0

    # --- route + retrieve through the async micro-batch queue: each
    # request submits independently (concurrent callers), the queue
    # coalesces them into routed batches. With --live a writer thread
    # streams upserts/deletes into the corpus while requests fly ---
    writer_stats = {"upserts": 0, "deletes": 0}
    stop_writer = threading.Event()
    # cap the stream below one delta mirror chunk: the first routed batch
    # pays one delta-kernel compile and every later search reuses it (an
    # unbounded writer would grow the delta mid-compile and force a
    # recompile at every chunk crossing)
    writer_budget = 400

    def writer():
        wrng = np.random.default_rng(42)
        while not stop_writer.is_set() and \
                writer_stats["upserts"] < writer_budget:
            src = wrng.integers(0, ds.n, size=8)
            ids = svc.index.upsert(
                ds.vectors[src] + wrng.normal(
                    scale=0.01, size=(8, ds.dim)).astype(np.float32),
                ds.bitmaps[src])
            writer_stats["upserts"] += len(ids)
            if writer_stats["upserts"] % 32 == 0:
                svc.index.delete(ids[:2])
                writer_stats["deletes"] += 2
            time.sleep(0.01)

    t0 = time.perf_counter()
    retrieved = np.full((b, 5), -1, np.int32)
    wt = None
    if args.live:
        wt = threading.Thread(target=writer, daemon=True)
        wt.start()
    replay_tags: list = []
    with AsyncBatchQueue(serving, max_batch=16, max_wait_ms=20.0) as queue:
        futs = [queue.submit(emb[i], qbms[i], preds[i], k=5)
                for i in range(b)]
        for i, f in enumerate(futs):
            retrieved[i] = f.result(timeout=300).ids
        if args.cache:
            # replay the round — repeat traffic is the cache's case;
            # hits resolve at submit, before the queue ever batches
            rfuts = [queue.submit(emb[i], qbms[i], preds[i], k=5)
                     for i in range(b)]
            replay_tags = [f.result(timeout=300).cache for f in rfuts]
        qstats = queue.stats()
    if wt is not None:
        stop_writer.set()
        wt.join(timeout=30)
    t_retrieve = time.perf_counter() - t0
    print(f"queue: {qstats['batches']} micro-batches for "
          f"{qstats['queries']} requests "
          f"(largest {qstats['max_batch_seen']}, depth "
          f"{qstats['max_queue_depth']}, "
          f"flushes {qstats['flush_reasons']})")
    if args.cache:
        cs = serving.stats()
        print(f"cache: replay served "
              f"{sum(t is not None for t in replay_tags)}/{b} from cache "
              f"({qstats['cache_hits']} at submit; exact "
              f"{cs['hits_exact']}, semantic {cs['hits_semantic']}, "
              f"misses {cs['misses']}, hit_rate {cs['hit_rate']}, "
              f"evictions ttl/stale/cap {cs['evictions_ttl']}/"
              f"{cs['evictions_stale']}/{cs['evictions_capacity']})")
    if sink is not None:
        ts = sink.stats()
        print(f"telemetry: {ts['queries']} events, p50 "
              f"{ts['latency_us']['p50']:.0f} us, p99 "
              f"{ts['latency_us']['p99']:.0f} us, by_method "
              f"{ts['by_method']}, reservoir {ts['reservoir']['size']}")
    if args.online_router:
        adapter = OnlineRouterAdapter(svc, sink, store=store,
                                      drift_threshold=0.05,
                                      min_samples=16, retrain_epochs=40,
                                      seed=3, slo=slo_eng)
        rep = adapter.step()
        print(f"adapter: audited {rep['samples']} sampled queries, "
              f"max_drift {rep['max_drift']:.3f}, table v"
              f"{rep['table_version']}, retrained={rep['retrained']}, "
              f"promoted={rep['promoted']}"
              + (f", artifact {rep['artifact']}" if "artifact" in rep
                 else ""))
    if args.live:
        st = svc.index.stats()
        print(f"live writer: {writer_stats['upserts']} upserts, "
              f"{writer_stats['deletes']} deletes concurrent with "
              f"serving (delta={st['delta_rows']} rows, "
              f"n_live={st['n_live']})")
        # with a store, compaction commits the new generation through
        # the manifest before the old segment is retired
        gen = store.compact() if store is not None else svc.index.compact()
        st = svc.index.stats()
        print(f"compacted -> generation {gen}: base_n={st['base_n']}, "
              f"delta_rows={st['delta_rows']}")
        # one more request round from the freshly swapped base
        with AsyncBatchQueue(serving, max_batch=16,
                             max_wait_ms=20.0) as queue:
            futs = [queue.submit(emb[i], qbms[i], preds[i], k=5)
                    for i in range(min(b, 8))]
            for i, f in enumerate(futs):
                retrieved[i] = f.result(timeout=300).ids
        print("post-compact serving OK")

    # --- generate conditioned on retrieval (ids appended as tokens) ---
    t0 = time.perf_counter()
    aug = [list(np.asarray(prompts[i])) +
           [int(x) % cfg.vocab for x in retrieved[i] if x >= 0][:4]
           for i in range(b)]
    width = max(len(a) for a in aug)
    aug = [a + [0] * (width - len(a)) for a in aug]
    out = generate(params, cfg, aug, max_new=8, ctx=ctx)
    t_gen = time.perf_counter() - t0

    print(f"served {b} requests: embed {t_embed*1e3:.0f} ms, "
          f"route+retrieve {t_retrieve*1e3:.0f} ms "
          f"({t_retrieve/b*1e6:.0f} us/req), generate {t_gen*1e3:.0f} ms")
    print("sample generations:", out[:2].tolist())
    hit = (retrieved >= 0).any(1).mean()
    print(f"retrieval hit rate: {hit:.2f}")
    if tracer is not None:
        from repro.common import artifacts_dir
        ts = tracer.stats()
        flight = tracer.flight()
        out_dir = artifacts_dir("serve")
        tracer.dump_flight_json(os.path.join(out_dir, "flight.json"))
        with open(os.path.join(out_dir, "trace_perfetto.json"), "w") as f:
            f.write(tracer.perfetto_json())
        print(f"trace: {ts['traces']} traces ({ts['kept']} kept, "
              f"{ts['slow']} slow, {ts['errors']} errored); flight + "
              f"Perfetto JSON -> {out_dir}")
        if flight:
            worst = max(flight, key=lambda r: r["duration_ms"])
            root = worst["root"]
            print(f"  slowest: {root.name} {worst['duration_ms']:.1f} ms "
                  f"[{worst['reason']}] {worst['annotations']}")
            for child in root.children:
                print(f"    {child.name}: {child.duration_s*1e3:.1f} ms "
                      f"{child.attrs}")
    if slo_eng is not None:
        slo_eng.evaluate()
        alerts = slo_eng.alerts()
        print(f"slo: state {slo_eng.state()}, "
              f"{slo_eng.stats()['evaluations']} evaluation(s), "
              f"{len(alerts)} alert(s)")
        for a in alerts[-2:]:
            print(f"  alert {a.objective} burn {a.burn_long:.1f}x "
                  f"(window {a.window[0]:.0f}s/{a.window[1]:.0f}s), "
                  f"{len(a.trace_ids)} trace id(s), "
                  f"provenance {a.provenance}")
    if obslog is not None:
        obslog.flush()
        os_ = obslog.stats()
        print(f"obslog: {os_['emitted']} wide events emitted, "
              f"{os_['written']} written, {os_['dropped']} dropped, "
              f"{os_['file_bytes']} bytes -> {os_['path']}")
    if args.slo or args.obslog:
        snap = get_ledger().snapshot()
        held = {k: sum(o["leases"] for o in v.values())
                for k, v in snap["held"].items()}
        print(f"ledger: held {held or '{}'}, "
              f"{len(snap['gauges'])} collector(s), "
              f"{len(snap['leaks'])} leak(s) past "
              f"{get_ledger().leak_age_s:.0f}s")
    if metrics_srv is not None:
        import urllib.request
        n_lines = len(urllib.request.urlopen(
            metrics_srv.url + "/metrics", timeout=5).read().splitlines())
        print(f"metrics: final scrape {n_lines} exposition lines")
        metrics_srv.close()
    if args.cache:
        serving.close()          # drop entries; the service stays open
    if store is not None:
        store.checkpoint()       # fold this session's WAL into a segment
        st = store.stats()
        print(f"persisted store generation {st['store_generation']} at "
              f"{st['path']} (segment {st['segment']}) — rerun with the "
              f"same --data-dir to restore")
        store.close()
    else:
        svc.index.close()
    if obslog is not None:
        obslog.close()           # the atexit post-mortem still reads stats


if __name__ == "__main__":
    main()
