"""Offline stage end-to-end: collect training data over the six training
datasets, sweep every method's parameter space into the benchmark table B,
train the per-method MLP regressors, and validate on the five unseen
datasets — the paper's full §6 pipeline.

    PYTHONPATH=src python examples/train_router.py [--queries 200]
"""

import argparse

import numpy as np

from repro.core import training as T
from repro.core.oracle import oracle_recall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    coll_train, coll_val, router = T.build_all(
        n_queries=args.queries, force=args.force, verbose=True)
    print(f"\ntable B entries: {len(router.table.entries)}")

    agg, agg_o = [], []
    print(f"{'dataset':16s} {'pred':9s} {'router':>7s} {'oracle':>7s}")
    for (ds, pt), cell in sorted(coll_val.cells.items()):
        x, _, _ = T.assemble_xy(
            T.Collection(cells={(ds, pt): cell}, table=coll_val.table),
            router.feature_names)
        dec = router.route_from_predictions(
            router.predict_recalls_from_features(x), ds, pt, 0.9)
        rec = np.array([cell.recall[m][i] for i, (m, _) in enumerate(dec)])
        orc = oracle_recall(coll_val, ds, pt)
        agg.append(rec)
        agg_o.append(orc)
        print(f"{ds:16s} {pt:<9d} {rec.mean():7.4f} {orc.mean():7.4f}")
    print(f"\nAGGREGATE router={np.concatenate(agg).mean():.4f} "
          f"oracle={np.concatenate(agg_o).mean():.4f} "
          f"(paper: 0.986 with 0.9% oracle gap)")


if __name__ == "__main__":
    main()
