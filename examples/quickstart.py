"""Quickstart: build a filtered-ANN dataset, open a `FilteredIndex` over
it, run every method on one query batch, then serve the query-aware ML
router through `RouterService` — including a save→load round-trip of the
versioned router artifact.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile
from collections import Counter

import numpy as np

from repro.ann import bench
from repro.ann.dataset import recall_at_k
from repro.ann.index import FilteredIndex, QueryBatch
from repro.ann.methods import ALL_METHODS
from repro.ann.predicates import Predicate
from repro.ann.service import RouterService
from repro.core import training as T
from repro.core.router import MLRouter
from repro.data.ann_synth import DatasetSpec, synthesize, make_queries


def main():
    # 1. a small labelled vector dataset (Zipf labels over Gaussian clusters)
    spec = DatasetSpec("demo", 4000, 48, 64, 8, 12, 1.3, 2.0, 0.5, 0.3, 42)
    ds = synthesize(spec)
    print(f"dataset: {ds.n} vectors, dim {ds.dim}, |U|={ds.universe}, "
          f"{ds.n_groups} unique label sets")

    # 2. one owned serving handle; run every method per predicate type
    fx = FilteredIndex(ds)
    for pred in (Predicate.EQUALITY, Predicate.AND, Predicate.OR):
        qs = make_queries(ds, pred, 50, seed=1)
        print(f"\n== {pred.name} (mean selectivity "
              f"{np.mean([ds.selectivity(qs.bitmaps[i], pred) for i in range(50)]):.3f}) ==")
        for name, m in ALL_METHODS.items():
            st = m.param_settings()[-1]
            r = bench.run_method(fx, m, st, qs)
            print(f"  {name:11s} [{st.ps_id:6s}] recall@10={r.mean_recall:.3f} "
                  f"QPS={r.qps:8.1f}")

    # 3. train the query-aware router on this dataset and serve through it
    coll = T.collect({"demo": fx}, n_queries=60, seed=0, verbose=False)
    router = T.train_router(coll, coll.table, epochs=80)
    svc = RouterService(fx, router, t=0.9)
    qs = make_queries(ds, Predicate.AND, 50, seed=9)
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, k=10)
    res = svc.search(batch)
    rec = recall_at_k(res.ids, qs.ground_truth).mean()
    print(f"\nML router (T=0.9): recall@10={rec:.3f}, decisions="
          f"{Counter(m for m, _ in res.decisions).most_common()}")
    print(f"stage timings: route {res.timings['route_s']*1e3:.1f} ms, "
          f"search {res.timings['search_s']*1e3:.1f} ms")
    exp = svc.explain(batch)[0]
    print(f"explain(q0): chose {exp.method}/{exp.ps_id}, "
          f"r̂={ {m: round(v, 3) for m, v in exp.r_hat.items()} }, "
          f"passing={exp.passing}")

    # 4. versioned artifact round-trip reproduces identical decisions
    art = os.path.join(tempfile.mkdtemp(prefix="repro_router_"), "router")
    router.save(art)
    res2 = RouterService(fx, MLRouter.load(art), t=0.9).search(batch)
    assert res2.decisions == res.decisions, "artifact round-trip diverged"
    print(f"artifact round-trip ({art}): identical routing decisions "
          f"on {batch.q} queries")
    fx.close()


if __name__ == "__main__":
    main()
