"""Quickstart: build a filtered-ANN dataset, run every method on one query
batch, then route with the query-aware ML router.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.ann import bench
from repro.ann.dataset import recall_at_k
from repro.ann.methods import ALL_METHODS, CANDIDATE_METHODS
from repro.ann.predicates import Predicate
from repro.core import training as T
from repro.data.ann_synth import DatasetSpec, synthesize, make_queries


def main():
    # 1. a small labelled vector dataset (Zipf labels over Gaussian clusters)
    spec = DatasetSpec("demo", 4000, 48, 64, 8, 12, 1.3, 2.0, 0.5, 0.3, 42)
    ds = synthesize(spec)
    print(f"dataset: {ds.n} vectors, dim {ds.dim}, |U|={ds.universe}, "
          f"{ds.n_groups} unique label sets")

    # 2. one query workload per predicate type; run every method
    for pred in (Predicate.EQUALITY, Predicate.AND, Predicate.OR):
        qs = make_queries(ds, pred, 50, seed=1)
        print(f"\n== {pred.name} (mean selectivity "
              f"{np.mean([ds.selectivity(qs.bitmaps[i], pred) for i in range(50)]):.3f}) ==")
        for name, m in ALL_METHODS.items():
            st = m.param_settings()[-1]
            r = bench.run_method(ds, m, st, qs)
            print(f"  {name:11s} [{st.ps_id:6s}] recall@10={r.mean_recall:.3f} "
                  f"QPS={r.qps:8.1f}")

    # 3. train the query-aware router on this dataset and route
    coll = T.collect({"demo": ds}, CANDIDATE_METHODS, n_queries=60,
                     seed=0, verbose=False)
    router = T.train_router(coll, coll.table, epochs=80)
    qs = make_queries(ds, Predicate.AND, 50, seed=9)
    ids, decisions = router.route_and_search(
        ds, qs.vectors, qs.bitmaps, Predicate.AND, 10, t=0.9,
        methods_impl=CANDIDATE_METHODS)
    rec = recall_at_k(ids, qs.ground_truth).mean()
    from collections import Counter
    print(f"\nML router (T=0.9): recall@10={rec:.3f}, decisions="
          f"{Counter(m for m, _ in decisions).most_common()}")


if __name__ == "__main__":
    main()
