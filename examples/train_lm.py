"""Fault-tolerant LM training demo: trains a reduced-config model on the
synthetic bigram stream with checkpointing, straggler monitoring, and
clean preemption (send SIGUSR1 to trigger a checkpoint-and-exit).

    PYTHONPATH=src python examples/train_lm.py --arch internlm2-1.8b \
        --steps 40 --ckpt /tmp/lm_ckpt
Re-running the same command resumes bitwise from the checkpoint.
"""

import argparse

from repro.configs.base import get_smoke_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    _, _, hist = train_loop(
        cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        ckpt_dir=args.ckpt, save_every=10, accum=args.accum, lr=2e-3,
        log_every=5)
    if hist:
        print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over "
              f"{len(hist)} steps "
              f"(median step {sorted(h['step_time_s'] for h in hist)[len(hist)//2]:.2f}s)")


if __name__ == "__main__":
    main()
